//! The `BatchTransform` contract: `apply_batch` must reproduce the
//! per-row `apply` path **bit-for-bit** (same seeded instance, same
//! inputs) for SRHT, CountSketch, TensorSRHT and PolySketch — the batched
//! implementations reuse per-thread scratch but reorder no
//! floating-point operation. Outputs are also checked against dirty
//! (pre-filled) output buffers, since the serving path reuses them.

use ntk_sketch::rng::Rng;
use ntk_sketch::tensor::Mat;
use ntk_sketch::transforms::{
    BatchTransform, CountSketch, GaussianJl, LeafMode, PolySketch, Srht, TensorSrht,
};

/// A garbage-filled output buffer: apply_batch must overwrite every slot.
fn dirty(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, rng.gauss_vec(rows * cols))
}

#[test]
fn srht_batch_matches_per_row_bitwise() {
    let mut rng = Rng::new(7001);
    for &(d, m, n) in &[(10usize, 7usize, 33usize), (128, 64, 9), (300, 111, 5), (64, 64, 1)] {
        let s = Srht::new(d, m, &mut rng);
        let x = Mat::from_vec(n, d, rng.gauss_vec(n * d));
        let mut out = dirty(&mut rng, n, m);
        s.apply_batch(&x, &mut out);
        for i in 0..n {
            assert_eq!(out.row(i), &s.apply(x.row(i))[..], "d={d} m={m} row {i}");
        }
    }
}

#[test]
fn countsketch_batch_matches_per_row_bitwise() {
    let mut rng = Rng::new(7002);
    for &(d, m, s_col, n) in &[(40usize, 16usize, 1usize, 21usize), (100, 64, 4, 8), (7, 5, 2, 3)] {
        let cs = CountSketch::new(d, m, s_col, &mut rng);
        let mut x = Mat::from_vec(n, d, rng.gauss_vec(n * d));
        // sprinkle exact zeros — the scatter loop skips them
        for i in 0..n {
            x.row_mut(i)[i % d] = 0.0;
        }
        let mut out = dirty(&mut rng, n, m);
        cs.apply_batch(&x, &mut out);
        for i in 0..n {
            assert_eq!(out.row(i), &cs.apply(x.row(i))[..], "d={d} m={m} row {i}");
        }
    }
}

#[test]
fn tensor_srht_batch_matches_per_row_bitwise() {
    let mut rng = Rng::new(7003);
    for &(d1, d2, m, n) in &[(12usize, 9usize, 17usize, 13usize), (64, 64, 64, 6), (5, 33, 8, 2)] {
        let ts = TensorSrht::new(d1, d2, m, &mut rng);
        let x = Mat::from_vec(n, d1, rng.gauss_vec(n * d1));
        let y = Mat::from_vec(n, d2, rng.gauss_vec(n * d2));
        let mut out = dirty(&mut rng, n, m);
        ts.apply_batch(&x, &y, &mut out);
        for i in 0..n {
            assert_eq!(
                out.row(i),
                &ts.apply(x.row(i), y.row(i))[..],
                "d1={d1} d2={d2} row {i}"
            );
        }
    }
}

#[test]
fn polysketch_batch_matches_per_row_bitwise() {
    let mut rng = Rng::new(7004);
    for &(p, d, m, n) in &[(1usize, 24usize, 16usize, 7usize), (2, 16, 32, 5), (5, 10, 24, 4)] {
        for mode in [LeafMode::Srht, LeafMode::Osnap(2)] {
            let q = PolySketch::new(p, d, m, mode, &mut rng);
            let x = Mat::from_vec(n, d, rng.gauss_vec(n * d));
            let mut out = dirty(&mut rng, n, m);
            q.apply_batch(&x, &mut out);
            for i in 0..n {
                assert_eq!(
                    out.row(i),
                    &q.sketch_power(x.row(i))[..],
                    "p={p} mode={mode:?} row {i}"
                );
            }
        }
    }
}

#[test]
fn gaussian_jl_batch_matches_per_row_bitwise() {
    let mut rng = Rng::new(7005);
    let g = GaussianJl::new(19, 11, &mut rng);
    let x = Mat::from_vec(6, 19, rng.gauss_vec(6 * 19));
    let mut out = dirty(&mut rng, 6, 11);
    g.apply_batch(&x, &mut out);
    for i in 0..6 {
        assert_eq!(out.row(i), &g.apply(x.row(i))[..], "row {i}");
    }
}

#[test]
fn apply_batch_alloc_equals_apply_batch() {
    let mut rng = Rng::new(7006);
    let s = Srht::new(50, 20, &mut rng);
    let x = Mat::from_vec(12, 50, rng.gauss_vec(600));
    let a = s.apply_batch_alloc(&x);
    let mut b = dirty(&mut rng, 12, 20);
    s.apply_batch(&x, &mut b);
    assert_eq!(a.data, b.data);
    assert_eq!((a.rows, a.cols), (12, 20));
}

#[test]
fn batch_respects_thread_count_override() {
    // parity must hold regardless of how rows are split into blocks —
    // exercise the single-thread path explicitly via NTK_THREADS.
    // (env var is process-wide; this test only *reads* a forced value if
    // the harness set one, so just run a tall-and-thin case that forces
    // multiple blocks on any thread count.)
    let mut rng = Rng::new(7007);
    let s = Srht::new(8, 4, &mut rng);
    let n = 257; // odd, never divides evenly into blocks
    let x = Mat::from_vec(n, 8, rng.gauss_vec(n * 8));
    let mut out = dirty(&mut rng, n, 4);
    s.apply_batch(&x, &mut out);
    for i in 0..n {
        assert_eq!(out.row(i), &s.apply(x.row(i))[..], "row {i}");
    }
}

#[test]
#[should_panic(expected = "apply_batch")]
fn apply_batch_rejects_shape_mismatch() {
    let mut rng = Rng::new(7008);
    let s = Srht::new(10, 6, &mut rng);
    let x = Mat::from_vec(3, 10, rng.gauss_vec(30));
    let mut out = Mat::zeros(3, 7); // wrong output dim
    s.apply_batch(&x, &mut out);
}
