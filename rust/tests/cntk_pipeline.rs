//! CNTK production-family integration tests (ISSUE 5): the batched
//! GEMM-backed pipeline must be **bit-for-bit** identical to the
//! per-image path at adversarial batch shapes, the family must round-trip
//! through the model store like every other vector family, and the
//! coordinator's `NativeBackend::run_into` must serve it unchanged.

use ntk_sketch::cntk::Image;
use ntk_sketch::coordinator::{BatchBackend, NativeBackend};
use ntk_sketch::features::cntk_sketch::{CntkSketch, CntkSketchConfig};
use ntk_sketch::features::{Featurizer, ImageFeaturizer};
use ntk_sketch::model::{FeaturizerSpec, SavedModel};
use ntk_sketch::regression::RidgeRegressor;
use ntk_sketch::rng::Rng;
use ntk_sketch::tensor::Mat;

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (p, q)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(p.to_bits(), q.to_bits(), "{what}: index {i}: {p:?} vs {q:?}");
    }
}

fn rand_images(rng: &mut Rng, n: usize, h: usize, w: usize, c: usize) -> Vec<Image> {
    (0..n).map(|_| Image::from_vec(h, w, c, rng.gauss_vec(h * w * c))).collect()
}

fn small_cfg() -> CntkSketchConfig {
    CntkSketchConfig { depth: 2, q: 3, p1: 1, p0: 1, r: 32, s: 32, m_inner: 32, s_out: 16 }
}

#[test]
fn batched_matches_per_image_at_adversarial_shapes() {
    // batch sizes straddling the GEMM microkernel tile (MR = 8) plus the
    // degenerate batch of one; non-square and 1-channel geometries.
    let mut rng = Rng::new(9001);
    for &(h, w, c) in &[(3usize, 5usize, 1usize), (4, 4, 3), (2, 7, 2)] {
        let sk = CntkSketch::new(h, w, c, small_cfg(), &mut rng);
        for &n in &[1usize, 7, 8, 9] {
            let imgs = rand_images(&mut rng, n, h, w, c);
            let batched = sk.transform_images(&imgs);
            assert_eq!((batched.rows, batched.cols), (n, 16));
            for (i, im) in imgs.iter().enumerate() {
                let single = sk.features(im);
                assert_bits_eq(
                    batched.row(i),
                    &single,
                    &format!("h={h} w={w} c={c} n={n} image {i}"),
                );
            }
        }
    }
}

#[test]
fn transform_into_overwrites_dirty_buffers() {
    // the serving contract: workers hand back the same output buffer
    // batch after batch, so every slot must be overwritten
    let mut rng = Rng::new(9002);
    let sk = CntkSketch::new(3, 3, 2, small_cfg(), &mut rng);
    let imgs = rand_images(&mut rng, 5, 3, 3, 2);
    let mut flat = Mat::zeros(5, sk.input_dim());
    for (i, im) in imgs.iter().enumerate() {
        flat.row_mut(i).copy_from_slice(&im.data);
    }
    let clean = sk.transform(&flat);
    let mut dirty = Mat::from_vec(5, 16, vec![f32::NAN; 5 * 16]);
    sk.transform_into(&flat, &mut dirty);
    assert_bits_eq(&dirty.data, &clean.data, "dirty-buffer transform_into");
}

#[test]
fn cntk_spec_round_trips_bit_identically_through_the_store() {
    // (config, seed) → featurizer reconstruction and ridge predictions
    // must survive the .ntkm encoding bit-for-bit, like every family
    let spec = FeaturizerSpec::CntkSketch {
        h: 4,
        w: 4,
        c: 3,
        depth: 2,
        q: 3,
        p1: 1,
        p0: 1,
        r: 32,
        s: 32,
        m_inner: 32,
        s_out: 16,
        seed: 91,
    };
    let d = spec.input_dim();
    assert_eq!(d, 48);
    let mut rng = Rng::new(92);
    let n = 24;
    let x = Mat::from_vec(n, d, rng.gauss_vec(n * d));
    let y = Mat::from_vec(n, 2, rng.gauss_vec(n * 2));
    let f = spec.build();
    let feats = f.transform(&x);
    let mut reg = RidgeRegressor::new(f.dim(), 2);
    reg.add_batch(&feats, &y);
    reg.solve(1e-2).unwrap();
    let weights = reg.weights().unwrap().clone();
    let reference = feats.matmul(&weights);
    let saved =
        SavedModel::new("cntk-rt", "cifar-like", 92, 1e-2, n as u64, spec, weights, &f);
    let loaded = SavedModel::from_bytes(&saved.to_bytes()).unwrap();
    assert_eq!(loaded.meta.family, "cntk");
    let model = loaded.build().unwrap();
    let pred = model.predict(&x);
    assert_bits_eq(&pred.data, &reference.data, "cntk store round trip");
}

#[test]
fn cntk_golden_rows_catch_determinism_drift() {
    let spec = FeaturizerSpec::CntkSketch {
        h: 3,
        w: 3,
        c: 2,
        depth: 2,
        q: 3,
        p1: 1,
        p0: 1,
        r: 16,
        s: 16,
        m_inner: 16,
        s_out: 8,
        seed: 93,
    };
    let f = spec.build();
    let weights = Mat::zeros(spec.feature_dim(), 1);
    let saved =
        SavedModel::new("cntk-drift", "cifar-like", 93, 1e-2, 8, spec, weights, &f);
    let mut drifted = SavedModel::from_bytes(&saved.to_bytes()).unwrap();
    if let FeaturizerSpec::CntkSketch { seed, .. } = &mut drifted.spec {
        *seed ^= 1;
    } else {
        panic!("expected cntk spec");
    }
    // pin the golden inputs so only the featurizer draw changes
    drifted.golden_x = saved.golden_x.clone();
    let err = drifted.build().unwrap_err();
    assert!(err.to_string().contains("golden"), "{err}");
    assert!(err.to_string().contains("determinism"), "{err}");
}

#[test]
fn cntk_model_serves_through_batched_run_into() {
    // the coordinator path: a store-loaded cntk model behind
    // NativeBackend must route through the batched transform_into and
    // match the in-process predictions bit-for-bit, padding included
    let spec = FeaturizerSpec::CntkSketch {
        h: 3,
        w: 4,
        c: 1,
        depth: 2,
        q: 3,
        p1: 1,
        p0: 1,
        r: 16,
        s: 16,
        m_inner: 16,
        s_out: 8,
        seed: 94,
    };
    let d = spec.input_dim();
    let mut rng = Rng::new(95);
    let n = 6;
    let x = Mat::from_vec(n, d, rng.gauss_vec(n * d));
    let y = Mat::from_vec(n, 1, rng.gauss_vec(n));
    let f = spec.build();
    let feats = f.transform(&x);
    let mut reg = RidgeRegressor::new(f.dim(), 1);
    reg.add_batch(&feats, &y);
    reg.solve(1e-2).unwrap();
    let weights = reg.weights().unwrap().clone();
    let reference = feats.matmul(&weights);
    let saved =
        SavedModel::new("cntk-serve", "cifar-like", 95, 1e-2, n as u64, spec, weights, &f);
    let model = SavedModel::from_bytes(&saved.to_bytes()).unwrap().build().unwrap();
    let batch = n + 2; // force pad rows
    let backend = NativeBackend {
        featurizer: Box::new(model) as Box<dyn Featurizer>,
        batch,
        input_dim: d,
    };
    let mut padded = Mat::zeros(batch, d);
    for i in 0..n {
        padded.row_mut(i).copy_from_slice(x.row(i));
    }
    let mut out = Mat::from_vec(batch, 1, vec![f32::NAN; batch]);
    backend.run_into(&padded, &mut out);
    assert_bits_eq(&out.data[..n], &reference.data, "cntk run_into vs in-process");
}

#[test]
fn image_and_flat_surfaces_agree() {
    // ImageFeaturizer::transform_images and Featurizer::transform over
    // flattened rows are one pipeline
    let mut rng = Rng::new(9003);
    let sk = CntkSketch::new(5, 3, 2, small_cfg(), &mut rng);
    let imgs = rand_images(&mut rng, 4, 5, 3, 2);
    let via_images = sk.transform_images(&imgs);
    let mut flat = Mat::zeros(4, sk.input_dim());
    for (i, im) in imgs.iter().enumerate() {
        flat.row_mut(i).copy_from_slice(&im.data);
    }
    let via_flat = Featurizer::transform(&sk, &flat);
    assert_bits_eq(&via_images.data, &via_flat.data, "image vs flat surface");
}
