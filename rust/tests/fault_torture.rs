//! Crash-consistency and self-healing torture tests (DESIGN.md §11).
//!
//! These tests install *process-global* fault plans via
//! [`ntk_sketch::fault::install`], so they serialize on one mutex and
//! every test clears the plan on exit (even on panic, via a drop guard).
//! All schedules are driven by the printed `TORTURE_SEED`, so any
//! failure replays bit-identically by re-running the same test binary.
//!
//! 1. Crash-consistency enumeration: for every store-path fault site,
//!    inject a fault at *every* numbered visit of a save+checkpoint
//!    sequence; recovery must always land on a complete, golden-verified
//!    old or new version — never a corrupt or half-visible one.
//! 2. The registry watcher absorbs a failed hot-swap load (counted in
//!    `swap_failures`) and converges to the new version on retry.
//! 3. A shard worker panic fails exactly the in-flight request with a
//!    typed error; the next request on the same connection succeeds.
//! 4. A torn wire frame is absorbed by [`RetryingClient`]; the caller
//!    still gets bit-identical predictions.
//! 5. Distributed-train crash consistency: a crash at any numbered
//!    visit of any fault site in a shard-train + merge sequence
//!    (including `merge.read`) leaves either the complete merged model
//!    or a rerunnable shard set — and the rerun converges to
//!    predictions bit-identical to the unfaulted merge. Every injection
//!    point is replayed twice for bit-identical recovery.

use ntk_sketch::fault;
use ntk_sketch::model::{FeaturizerSpec, Registry, SavedModel, TrainCheckpoint};
use ntk_sketch::regression::RidgeRegressor;
use ntk_sketch::rng::Rng;
use ntk_sketch::serve::{
    InferenceError, InferenceSession, RetryPolicy, RetryingClient, ServeOptions, TcpServer,
    TcpSession,
};
use ntk_sketch::tensor::Mat;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Every fault schedule in this file derives from this seed; it is
/// printed on entry so a failure is replayable bit-for-bit.
const TORTURE_SEED: u64 = 0xFA17_0001;

const D: usize = 8;

/// Global-plan tests must not interleave: they share the process-wide
/// fault plan. Lock poisoning is expected (a failing test panics while
/// holding the guard) and harmless — the drop guard already cleared.
static LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> std::sync::MutexGuard<'static, ()> {
    println!("fault torture: NTK_FAULT_SEED={TORTURE_SEED} (replay with this seed)");
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Clears the process-global fault plan when dropped, so a panicking
/// assertion cannot leak an active plan into the next test.
struct ClearOnDrop;
impl Drop for ClearOnDrop {
    fn drop(&mut self) {
        fault::clear();
    }
}

/// A real spec-built model; the featurizer is pinned by a fixed spec
/// seed so two models differ only in their ridge weights.
fn saved_model(name: &str, weight_seed: u64) -> SavedModel {
    let spec = FeaturizerSpec::NtkRf {
        d: D,
        depth: 2,
        m0: 16,
        m1: 32,
        ms: 16,
        leverage_sweeps: 0,
        seed: 100,
    };
    let f = spec.build();
    let mut rng = Rng::new(weight_seed);
    let weights = Mat::from_vec(f.dim(), 1, rng.gauss_vec(f.dim()));
    SavedModel::new(name, "synthetic", weight_seed, 1e-3, 64, spec, weights, &f)
}

fn temp_root(tag: &str) -> PathBuf {
    let root =
        std::env::temp_dir().join(format!("ntk_torture_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn batch(seed: u64, rows: usize) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(rows, D, rng.gauss_vec(rows * D))
}

/// The stateful sequence under torture: advance the model a version and
/// write a training checkpoint. Both steps may fail under injection —
/// failures are the point; recovery is asserted afterwards.
fn save_and_checkpoint(root: &PathBuf, v2: &SavedModel, ck: &TrainCheckpoint) {
    let registry = Registry::open(root);
    let _ = registry.save(v2);
    let _ = registry.save_checkpoint(ck);
}

/// What a fresh process observes after the crash: which version the
/// registry resolves (golden-verified), and whether a checkpoint is
/// visible. Compared across replays for bit-identical recovery.
#[derive(Debug, PartialEq)]
struct Recovery {
    version: u32,
    ckpt_visible: bool,
}

/// Run the sequence with `site:at=k` installed, then recover with faults
/// cleared, asserting the store's crash-consistency contract.
fn crash_and_recover(site: &str, k: u64, tag: &str) -> Recovery {
    let root = temp_root(tag);
    let v1 = saved_model("tort", 1);
    let v2 = saved_model("tort", 2);
    let registry = Registry::open(&root);
    registry.save(&v1).expect("clean v1 save");
    let x = batch(7, 4);
    let pred1 = v1.build().unwrap().predict(&x).data;
    let pred2 = v2.build().unwrap().predict(&x).data;
    assert_ne!(pred1, pred2, "versions must be distinguishable");

    let ck = TrainCheckpoint::capture(
        v2.meta.clone(),
        v2.spec.clone(),
        128,
        32,
        1,
        &RidgeRegressor::new(v2.spec.feature_dim(), 1),
    );
    {
        let _clear = ClearOnDrop;
        fault::install(&format!("{site}:at={k}"), TORTURE_SEED).expect("install plan");
        save_and_checkpoint(&root, &v2, &ck);
    }

    // a "fresh process": new registry handles, no fault plan
    let registry = Registry::open(&root);
    let loaded = registry
        .load("tort", None)
        .unwrap_or_else(|e| panic!("{site}:at={k}: recovery must resolve a version: {e}"));
    let model = loaded
        .build()
        .unwrap_or_else(|e| panic!("{site}:at={k}: recovered artifact must verify: {e}"));
    let version = model.meta.version;
    assert!(
        version == 1 || version == 2,
        "{site}:at={k}: recovered v{version}, expected the old or new version"
    );
    // half-visible would mean predictions matching neither version
    let got = model.predict(&x).data;
    let want = if version == 1 { &pred1 } else { &pred2 };
    assert_eq!(&got, want, "{site}:at={k}: recovered v{version} predicts wrong values");
    // a checkpoint is either absent or complete — find_checkpoint decodes
    // (CRC + format checks); a torn file would error differently, but
    // rename atomicity means it simply does not exist
    let ckpt_visible = match registry.find_checkpoint(None) {
        Ok((name, found)) => {
            assert_eq!((name.as_str(), found.batch_rows), ("tort", 32));
            true
        }
        Err(_) => false,
    };
    let _ = std::fs::remove_dir_all(&root);
    Recovery { version, ckpt_visible }
}

#[test]
fn every_store_fault_site_recovers_to_a_complete_version() {
    let _lock = serialize();
    for site in ["store.write", "store.fsync", "store.rename", "registry.latest"] {
        // dry run with a never-firing plan to count this sequence's
        // visits of `site` — the enumeration below covers every one
        let n = {
            let root = temp_root("dry");
            let v1 = saved_model("tort", 1);
            let v2 = saved_model("tort", 2);
            Registry::open(&root).save(&v1).expect("clean v1 save");
            let ck = TrainCheckpoint::capture(
                v2.meta.clone(),
                v2.spec.clone(),
                128,
                32,
                1,
                &RidgeRegressor::new(v2.spec.feature_dim(), 1),
            );
            let _clear = ClearOnDrop;
            fault::install(&format!("{site}:p=0"), TORTURE_SEED).expect("install dry plan");
            save_and_checkpoint(&root, &v2, &ck);
            let n = fault::visits(site);
            let _ = std::fs::remove_dir_all(&root);
            n
        };
        assert!(n >= 1, "{site}: the sequence never reached this site");

        for k in 0..n {
            let first = crash_and_recover(site, k, "a");
            // deterministic replay: the identical seed + schedule lands
            // on the identical recovery outcome
            let second = crash_and_recover(site, k, "b");
            assert_eq!(
                first, second,
                "{site}:at={k}: replay diverged (seed {TORTURE_SEED})"
            );
        }
        println!("torture: {site} survived all {n} injection points");
    }
}

/// Build the k shard checkpoints of one deterministic fit, entirely in
/// memory (the torture sequence writes them through the faulted store).
fn torture_shards(k: usize) -> (Vec<TrainCheckpoint>, Mat, Vec<f32>) {
    let spec = FeaturizerSpec::Rff { d: D, m: 32, sigma: 1.1, seed: 200 };
    let f = spec.build();
    let (n, batch_rows, outputs) = (48usize, 8usize, 1usize);
    let mut rng = Rng::new(0xD157);
    let x = Mat::from_vec(n, D, rng.gauss_vec(n * D));
    let y = Mat::from_vec(n, outputs, rng.gauss_vec(n * outputs));
    let meta = ntk_sketch::model::ModelMeta {
        name: "tm".into(),
        version: 0,
        family: spec.family().into(),
        dataset: "synthetic".into(),
        data_seed: 0xD157,
        lambda: 1e-2,
        n_seen: 0,
        input_dim: D,
        feature_dim: spec.feature_dim(),
        outputs,
    };
    let nb = n.div_ceil(batch_rows);
    let shards: Vec<TrainCheckpoint> = (0..k)
        .map(|i| {
            let (lo, hi) =
                ((nb * i / k * batch_rows).min(n), (nb * (i + 1) / k * batch_rows).min(n));
            let mut reg = RidgeRegressor::new(spec.feature_dim(), outputs);
            let mut at = lo;
            while at < hi {
                let stop = (at + batch_rows).min(hi);
                reg.add_batch(&f.transform(&x.slice_rows(at, stop)), &y.slice_rows(at, stop));
                at = stop;
            }
            TrainCheckpoint::capture(
                meta.clone(),
                spec.clone(),
                n as u64,
                batch_rows as u64,
                0,
                &reg,
            )
            .with_shard(i as u64, k as u64)
        })
        .collect();
    // the unfaulted merge is the reference artifact
    let (merged, mut reg) =
        ntk_sketch::model::merge_checkpoints(shards.clone()).expect("clean merge");
    reg.solve(merged.meta.lambda).expect("clean solve");
    let probe = batch(0xBEEF, 5);
    let reference = f.transform(&probe).matmul(reg.weights().unwrap()).data;
    (shards, probe, reference)
}

/// The distributed sequence under torture: persist every shard
/// checkpoint through the store, then merge them into a registry
/// version. Any step may fail under injection — recovery is asserted
/// by the caller.
fn shard_train_and_merge(root: &PathBuf, shards: &[TrainCheckpoint]) {
    let registry = Registry::open(root);
    for ck in shards {
        let _ = registry.save_shard_checkpoint(ck);
    }
    let mut read = Vec::new();
    for path in registry.list_shard_checkpoints("tm") {
        match Registry::read_shard_checkpoint(&path) {
            Ok(ck) => read.push(ck),
            Err(_) => return, // crashed mid-merge; shards stay on disk
        }
    }
    let Ok((merged, mut reg)) = ntk_sketch::model::merge_checkpoints(read) else {
        return; // incomplete shard set after a faulted write
    };
    if reg.solve(merged.meta.lambda).is_err() {
        return;
    }
    let f = merged.spec.build();
    let saved = SavedModel::new(
        "tm",
        &merged.meta.dataset,
        merged.meta.data_seed,
        merged.meta.lambda,
        merged.meta.n_seen,
        merged.spec.clone(),
        reg.weights().unwrap().clone(),
        &*f,
    );
    if registry.save(&saved).is_err() {
        return; // shard checkpoints deliberately survive a failed save
    }
    let _ = registry.clear_shard_checkpoints("tm");
}

/// What a fresh process observes after a crash in the shard+merge
/// sequence, compared across replays for bit-identical recovery.
#[derive(Debug, PartialEq)]
struct ShardRecovery {
    merged_before_rerun: bool,
    shards_left: usize,
}

fn shard_crash_and_recover(
    site: &str,
    k_at: u64,
    shards: &[TrainCheckpoint],
    probe: &Mat,
    reference: &[f32],
    tag: &str,
) -> ShardRecovery {
    let root = temp_root(tag);
    {
        let _clear = ClearOnDrop;
        fault::install(&format!("{site}:at={k_at}"), TORTURE_SEED).expect("install plan");
        shard_train_and_merge(&root, shards);
    }

    // a "fresh process": no fault plan, new handles
    let registry = Registry::open(&root);
    let shards_left = registry.list_shard_checkpoints("tm").len();
    let merged_before_rerun = match registry.load("tm", None) {
        Ok(loaded) => {
            // whatever resolved must be the COMPLETE merged artifact
            let model = loaded
                .build()
                .unwrap_or_else(|e| panic!("{site}:at={k_at}: torn merged model: {e}"));
            assert_eq!(
                model.predict(probe).data,
                reference,
                "{site}:at={k_at}: merged model predicts wrong values"
            );
            true
        }
        Err(_) => false,
    };
    if !merged_before_rerun {
        // old-state recovery: rerunning the sequence (shard retrain is
        // deterministic, so re-capturing is the same bytes) must land
        // the merged artifact
        shard_train_and_merge(&root, shards);
        let model = registry
            .load("tm", None)
            .unwrap_or_else(|e| panic!("{site}:at={k_at}: rerun must merge: {e}"))
            .build()
            .unwrap_or_else(|e| panic!("{site}:at={k_at}: rerun artifact torn: {e}"));
        assert_eq!(
            model.predict(probe).data,
            reference,
            "{site}:at={k_at}: rerun predicts wrong values"
        );
    }
    let _ = std::fs::remove_dir_all(&root);
    ShardRecovery { merged_before_rerun, shards_left }
}

#[test]
fn shard_merge_sequence_recovers_at_every_fault_site() {
    let _lock = serialize();
    let (shards, probe, reference) = torture_shards(3);
    for site in
        ["merge.read", "store.write", "store.fsync", "store.rename", "registry.latest"]
    {
        // dry run with a never-firing plan to count this sequence's
        // visits of `site`, then inject at every one of them
        let n = {
            let root = temp_root("sdry");
            let _clear = ClearOnDrop;
            fault::install(&format!("{site}:p=0"), TORTURE_SEED).expect("install dry plan");
            shard_train_and_merge(&root, &shards);
            let n = fault::visits(site);
            let _ = std::fs::remove_dir_all(&root);
            n
        };
        assert!(n >= 1, "{site}: the shard+merge sequence never reached this site");

        for k_at in 0..n {
            let first =
                shard_crash_and_recover(site, k_at, &shards, &probe, &reference, "sa");
            let second =
                shard_crash_and_recover(site, k_at, &shards, &probe, &reference, "sb");
            assert_eq!(
                first, second,
                "{site}:at={k_at}: replay diverged (seed {TORTURE_SEED})"
            );
        }
        println!("torture: shard+merge {site} survived all {n} injection points");
    }
}

#[test]
fn watcher_absorbs_a_failed_swap_load_and_converges() {
    let _lock = serialize();
    let _clear = ClearOnDrop;
    let root = temp_root("watch");
    let registry = Registry::open(&root);
    let v1 = saved_model("wt", 1);
    let v2 = saved_model("wt", 2);
    registry.save(&v1).expect("clean v1 save");
    let serving = registry.load("wt", None).unwrap().build().unwrap();

    // the watcher's FIRST load of the replacement fails (exactly as a
    // mid-write artifact would); the retry after backoff must succeed
    fault::install("swap.load:at=0", TORTURE_SEED).expect("install plan");
    let server = TcpServer::start(
        serving,
        Some((Registry::open(&root), "wt".to_string())),
        "127.0.0.1:0",
        ServeOptions { workers: 1, poll_ms: 10, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    registry.save(&v2).expect("clean v2 save");

    let mut sess = TcpSession::connect(&addr).unwrap();
    let deadline = Instant::now() + Duration::from_secs(15);
    let stats = loop {
        let stats = sess.stats().unwrap();
        if stats.version == 2 {
            break stats;
        }
        assert!(Instant::now() < deadline, "watcher never converged to v2: {stats:?}");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(stats.swap_failures >= 1, "the injected load failure must be counted");
    assert!(stats.swaps >= 1, "the retry must have swapped");
    drop(sess);
    server.join();
    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn shard_panic_fails_one_request_with_a_typed_error_then_heals() {
    let _lock = serialize();
    let _clear = ClearOnDrop;
    let saved = saved_model("sp", 1);
    let server = TcpServer::start(
        saved.build().unwrap(),
        None,
        "127.0.0.1:0",
        ServeOptions { workers: 1, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut sess = TcpSession::connect(&addr).unwrap();
    let x = batch(11, 4);
    let reference = saved.build().unwrap().predict(&x).data;

    // the worker's FIRST job panics mid-flight
    fault::install("shard.panic:at=0", TORTURE_SEED).expect("install plan");
    match sess.infer(&x) {
        Err(InferenceError::Io(msg)) => {
            assert!(msg.contains("panicked"), "typed panic error names the cause: {msg}")
        }
        other => panic!("expected a typed Io error from the panicked shard, got {other:?}"),
    }
    // same connection, same worker thread: the shard healed in place
    let out = sess.infer(&x).expect("the shard must serve after the panic");
    assert_eq!(out.data, reference, "post-panic predictions are bit-identical");
    let stats = sess.stats().unwrap();
    assert_eq!(stats.total.panics, 1, "exactly one panic counted: {stats:?}");
    assert!(stats.total.requests >= 2);
    drop(sess);
    server.join();
}

#[test]
fn torn_wire_frame_is_absorbed_by_the_retrying_client() {
    let _lock = serialize();
    let _clear = ClearOnDrop;
    let saved = saved_model("rw", 1);
    let server = TcpServer::start(
        saved.build().unwrap(),
        None,
        "127.0.0.1:0",
        ServeOptions { workers: 1, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let x = batch(13, 4);
    let reference = saved.build().unwrap().predict(&x).data;

    // the process's FIRST frame read after install fails — it lands on
    // either the client's HELLO read or the server's request read
    // (whichever the scheduler runs first); the retrying client absorbs
    // both shapes, reconnecting if its session broke
    fault::install("wire.read:at=0", TORTURE_SEED).expect("install plan");
    let mut client = RetryingClient::connect(&addr, RetryPolicy::default())
        .expect("connect retries through the torn read");
    let out = client.infer(&x).expect("inference retries through the torn read");
    assert_eq!(out.data, reference, "retried predictions are bit-identical");
    assert!(fault::visits("wire.read") >= 1, "the fault site was never reached");
    drop(client);
    server.join();
}
