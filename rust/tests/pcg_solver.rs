//! Iterative-solver integration tests: PCG against the Cholesky oracle
//! on real ridge systems (DESIGN.md §13).
//!
//! - `--solver pcg` must agree with the direct factorization on the
//!   same accumulated normal equations: weights within tolerance, and
//!   prediction fingerprints (crc32 under the rounding contract) equal
//!   — across well- and ill-conditioned grams and a λ sweep;
//! - the Nyström preconditioner must *pay for itself*: on a gram with
//!   a decaying head the preconditioned solve takes strictly fewer
//!   iterations than plain CG at the same tolerance and seed;
//! - solver reports are honest: iteration counts per right-hand side,
//!   preconditioner rank, converged flag — and seeded solves are
//!   bit-identical run to run.

use ntk_sketch::linalg::DMat;
use ntk_sketch::model::codec::crc32;
use ntk_sketch::regression::{
    solve_spd_pcg, PcgOpts, RidgeRegressor, SolverChoice, PCG_AUTO_MIN_DIM,
};
use ntk_sketch::rng::Rng;
use ntk_sketch::tensor::Mat;

/// Synthetic ridge problem with controllable conditioning: column j of
/// the feature matrix is scaled by `decay^j`. A fast decay yields a
/// gram that is a geometric head over a λn-floored tail — the sketched
/// NTK shape — with the spectrum span set by `decay^(2(m-1))`.
fn problem(n: usize, m: usize, outputs: usize, decay: f32, seed: u64) -> (Mat, Mat) {
    let mut rng = Rng::new(seed);
    let mut x = Mat::from_vec(n, m, rng.gauss_vec(n * m));
    for i in 0..n {
        for j in 0..m {
            *x.at_mut(i, j) *= decay.powi(j as i32);
        }
    }
    let y = Mat::from_vec(n, outputs, rng.gauss_vec(n * outputs));
    (x, y)
}

fn fit(x: &Mat, y: &Mat) -> RidgeRegressor {
    let mut reg = RidgeRegressor::new(x.cols, y.cols);
    reg.add_batch(x, y);
    reg
}

/// The prediction fingerprint under the rounding contract: quantize to
/// a 1e-4 grid (predictions are O(1) fits of unit-variance targets),
/// then crc32 the little-endian f32 bytes. Two solvers that both drove
/// the residual to 1e-10 land on the same fingerprint; a solver that
/// actually diverged cannot.
fn pred_crc(pred: &Mat) -> u32 {
    let mut bytes = Vec::with_capacity(pred.data.len() * 4);
    for &v in &pred.data {
        let q = (v as f64 * 1e4).round() as f32;
        bytes.extend_from_slice(&q.to_le_bytes());
    }
    crc32(&bytes)
}

fn max_rel_err(a: &[f32], b: &[f32]) -> f64 {
    let scale = b.iter().fold(0f64, |acc, &v| acc.max(v.abs() as f64)).max(1e-30);
    a.iter()
        .zip(b)
        .fold(0f64, |acc, (&p, &q)| acc.max((p as f64 - q as f64).abs()))
        / scale
}

#[test]
fn pcg_matches_cholesky_across_conditioning_and_lambda() {
    let (n, m, outputs) = (160usize, 96usize, 2usize);
    // decay 1.0 → benign Wishart gram (κ ≈ 60); 0.8 → a geometric head
    // spanning ~12 orders of magnitude into the λn floor
    for (cond_tag, decay) in [("well", 1.0f32), ("ill", 0.8f32)] {
        let (x, y) = problem(n, m, outputs, decay, 0xD1CE + decay.to_bits() as u64);
        // sweep floor 1e-5 keeps κ of the regularized system ≤ ~1e5,
        // an order above CG's f64 residual-stagnation limit at 1e-10
        for lambda in [1e-1f64, 1e-3, 1e-5] {
            let what = format!("{cond_tag}-conditioned, λ={lambda:.0e}");

            let mut chol = fit(&x, &y);
            let rep = chol.solve_with(lambda, SolverChoice::Chol).unwrap();
            assert_eq!(rep.solver, "chol", "{what}");

            let mut pcg = fit(&x, &y);
            let rep = pcg.solve_with(lambda, SolverChoice::Pcg).unwrap();
            assert_eq!(rep.solver, "pcg", "{what}");
            assert!(rep.converged, "{what}: pcg failed to converge: {rep:?}");
            assert_eq!(rep.iterations.len(), outputs, "{what}: one count per rhs");
            assert!(rep.iterations.iter().all(|&it| it > 0), "{what}");
            assert!(
                rep.rel_residual <= 1e-9,
                "{what}: residual {:.3e}",
                rep.rel_residual
            );

            // weights agree up to the conditioning the residual bound
            // allows (κ·tol); the oracle here is the factorization
            let wc = &chol.weights().unwrap().data;
            let wp = &pcg.weights().unwrap().data;
            let werr = max_rel_err(wp, wc);
            assert!(werr <= 2e-4, "{what}: weight divergence {werr:.3e}");

            // predictions are far better conditioned than weights (the
            // gram damps exactly the directions the solvers can differ
            // in), so the fingerprint contract is exact
            let pc = chol.predict(&x);
            let pp = pcg.predict(&x);
            let perr = max_rel_err(&pp.data, &pc.data);
            assert!(perr <= 1e-5, "{what}: prediction divergence {perr:.3e}");
            assert_eq!(
                pred_crc(&pc),
                pred_crc(&pp),
                "{what}: prediction crc mismatch (max rel err {perr:.3e})"
            );
        }
    }
}

#[test]
fn nystrom_preconditioner_cuts_iterations_and_is_seeded() {
    // Spectrum chosen so both solves converge well inside the cap and
    // the comparison is driven by structure, not luck: a geometric head
    // of 24 well-separated eigenvalues (2^0 … 2^-23) over a large
    // cluster pinned at 2^-24. Plain CG pays roughly one iteration per
    // distinct eigenvalue; a rank-32 Nyström sketch deflates the whole
    // head, leaving a point cluster it crosses in a handful.
    let m = 192usize;
    let mut a = DMat::zeros(m, m);
    for j in 0..m {
        *a.at_mut(j, j) = 0.5f64.powi(j.min(24) as i32);
    }
    let mut rng = Rng::new(0x5EED);
    let b = DMat::from_fn(m, 1, |_, _| rng.gauss());

    let base = PcgOpts {
        tol: 1e-10,
        max_iter: 2 * m,
        rank: 32,
        seed: 0xA11CE,
        precond: true,
    };
    let plain = PcgOpts { precond: false, ..base.clone() };
    let (_, rep_plain) = solve_spd_pcg(&a, &b, &plain).unwrap();
    let (_, rep_pre) = solve_spd_pcg(&a, &b, &base).unwrap();
    assert!(rep_plain.converged, "{rep_plain:?}");
    assert!(rep_pre.converged, "{rep_pre:?}");
    assert!(rep_pre.precond_rank > 0, "preconditioner must have been built");
    assert_eq!(rep_plain.precond_rank, 0, "plain CG must not build one");
    let (it_plain, it_pre) = (rep_plain.iterations[0], rep_pre.iterations[0]);
    assert!(
        it_pre < it_plain,
        "Nyström must cut iterations: {it_pre} (preconditioned) vs {it_plain} (plain)"
    );

    // same seed, same system → bit-identical report and solution
    let (x1, r1) = solve_spd_pcg(&a, &b, &base).unwrap();
    let (x2, r2) = solve_spd_pcg(&a, &b, &base).unwrap();
    assert_eq!(r1, r2, "seeded pcg reports must be reproducible");
    assert_eq!(x1.data.len(), x2.data.len());
    for (p, q) in x1.data.iter().zip(&x2.data) {
        assert_eq!(p.to_bits(), q.to_bits(), "seeded pcg solutions must be bitwise equal");
    }
}

#[test]
fn auto_solver_picks_by_dimension() {
    let (x, y) = problem(64, 32, 1, 1.0, 7);
    let mut reg = fit(&x, &y);
    let rep = reg.solve_with(1e-2, SolverChoice::Auto).unwrap();
    assert_eq!(rep.solver, "chol", "below the threshold auto must factorize");
    assert!(32 < PCG_AUTO_MIN_DIM);
}
