//! Model-store integration tests: the persistence contracts the
//! subsystem promises (DESIGN.md §8).
//!
//! - save→load→predict is **bit-identical** to the in-process pipeline,
//!   for every vector featurizer family;
//! - checkpoint/resume equals an uninterrupted streaming fit, bit for
//!   bit, through the on-disk encoding;
//! - corrupted / truncated / version-bumped files are refused with
//!   readable errors (never a panic, never a garbage model);
//! - the golden-row check catches determinism drift (wrong seed ⇒
//!   refusal);
//! - saved models store specs+seeds, not matrices: an NTKRF artifact is
//!   ≤1% of its materialized featurizer;
//! - the registry versions, points, lists and gc's correctly.

use ntk_sketch::coordinator::{BatchBackend, NativeBackend};
use ntk_sketch::features::Featurizer;
use ntk_sketch::model::{FeaturizerSpec, ModelMeta, Registry, SavedModel, TrainCheckpoint};
use ntk_sketch::regression::RidgeRegressor;
use ntk_sketch::rng::Rng;
use ntk_sketch::tensor::Mat;

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (p, q)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(p.to_bits(), q.to_bits(), "{what}: index {i}: {p:?} vs {q:?}");
    }
}

fn all_specs(d: usize) -> Vec<FeaturizerSpec> {
    vec![
        FeaturizerSpec::Rff { d, m: 48, sigma: 1.3, seed: 21 },
        FeaturizerSpec::NtkRf { d, depth: 2, m0: 16, m1: 48, ms: 16, leverage_sweeps: 0, seed: 22 },
        FeaturizerSpec::NtkRf { d, depth: 1, m0: 16, m1: 32, ms: 16, leverage_sweeps: 1, seed: 23 },
        FeaturizerSpec::NtkSketch {
            d,
            depth: 2,
            p1: 1,
            p0: 2,
            r: 32,
            s: 32,
            m_inner: 32,
            s_out: 24,
            osnap: 4,
            seed: 24,
        },
        FeaturizerSpec::NtkSketch {
            d,
            depth: 1,
            p1: 1,
            p0: 1,
            r: 16,
            s: 16,
            m_inner: 16,
            s_out: 16,
            osnap: 0,
            seed: 25,
        },
        FeaturizerSpec::NtkPolySketch { d, depth: 3, deg: 4, m_inner: 32, m_out: 24, seed: 26 },
        FeaturizerSpec::GradRfMlp { d, depth: 2, width: 8, seed: 27 },
        // the cntk family pins its own input dim (h·w·c), independent of d
        FeaturizerSpec::CntkSketch {
            h: 3,
            w: 3,
            c: 2,
            depth: 2,
            q: 3,
            p1: 1,
            p0: 1,
            r: 16,
            s: 16,
            m_inner: 16,
            s_out: 12,
            seed: 28,
        },
    ]
}

/// Fit a tiny ridge model over `spec`'s features on synthetic data.
fn fit_tiny(spec: &FeaturizerSpec, outputs: usize, seed: u64) -> (SavedModel, Mat, Mat) {
    let d = spec.input_dim();
    let mut rng = Rng::new(seed);
    let n = 40;
    let x = Mat::from_vec(n, d, rng.gauss_vec(n * d));
    let y = Mat::from_vec(n, outputs, rng.gauss_vec(n * outputs));
    let f = spec.build();
    let feats = f.transform(&x);
    let mut reg = RidgeRegressor::new(f.dim(), outputs);
    reg.add_batch(&feats, &y);
    reg.solve(1e-2).unwrap();
    let weights = reg.weights().unwrap().clone();
    // in-process reference predictions
    let reference = feats.matmul(&weights);
    let saved = SavedModel::new(
        "tiny",
        "synthetic",
        seed,
        1e-2,
        n as u64,
        spec.clone(),
        weights,
        &f,
    );
    (saved, x, reference)
}

#[test]
fn round_trip_bit_identical_every_family() {
    for spec in all_specs(7) {
        let (saved, x, reference) = fit_tiny(&spec, 2, 31);
        let bytes = saved.to_bytes();
        let loaded = SavedModel::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("{}: {e}", spec.family()));
        assert_eq!(loaded.meta.family, spec.family());
        let model = loaded.build().unwrap_or_else(|e| panic!("{}: {e}", spec.family()));
        let pred = model.predict(&x);
        assert_bits_eq(&pred.data, &reference.data, spec.family());
    }
}

#[test]
fn loaded_model_serves_through_batched_run_into() {
    // a reconstructed model behind `NativeBackend` must route through
    // `transform_into` and produce bit-identical predictions to the
    // in-process pipeline, including padded batch rows
    let spec = all_specs(6).remove(1); // NTKRF
    let (saved, x, reference) = fit_tiny(&spec, 1, 33);
    let model = SavedModel::from_bytes(&saved.to_bytes()).unwrap().build().unwrap();
    let batch = x.rows + 3; // force pad rows
    let backend = NativeBackend {
        featurizer: Box::new(model) as Box<dyn Featurizer>,
        batch,
        input_dim: spec.input_dim(),
    };
    let mut padded = Mat::zeros(batch, spec.input_dim());
    for i in 0..x.rows {
        padded.row_mut(i).copy_from_slice(x.row(i));
    }
    let mut out = Mat::from_vec(batch, 1, vec![f32::NAN; batch]);
    backend.run_into(&padded, &mut out);
    assert_bits_eq(
        &out.data[..x.rows],
        &reference.data,
        "run_into vs in-process",
    );
}

#[test]
fn checkpoint_resume_equals_uninterrupted_fit() {
    let spec = FeaturizerSpec::NtkRf {
        d: 8,
        depth: 2,
        m0: 16,
        m1: 48,
        ms: 16,
        leverage_sweeps: 0,
        seed: 41,
    };
    let f = spec.build();
    let mut rng = Rng::new(42);
    let (n, batch_rows, outputs) = (160, 32, 1);
    let x = Mat::from_vec(n, 8, rng.gauss_vec(n * 8));
    let y = Mat::from_vec(n, outputs, rng.gauss_vec(n));
    let meta = ModelMeta {
        name: "ck".into(),
        version: 0,
        family: spec.family().into(),
        dataset: "synthetic".into(),
        data_seed: 42,
        lambda: 1e-2,
        n_seen: 0,
        input_dim: 8,
        feature_dim: spec.feature_dim(),
        outputs,
    };

    // uninterrupted run
    let mut full = RidgeRegressor::new(spec.feature_dim(), outputs);
    for lo in (0..n).step_by(batch_rows) {
        let feats = f.transform(&x.slice_rows(lo, lo + batch_rows));
        full.add_batch(&feats, &y.slice_rows(lo, lo + batch_rows));
    }
    full.solve(1e-2).unwrap();

    // interrupted after 2 batches; checkpoint goes through the *binary
    // encoding*, not just memory
    let mut first = RidgeRegressor::new(spec.feature_dim(), outputs);
    for lo in (0..2 * batch_rows).step_by(batch_rows) {
        let feats = f.transform(&x.slice_rows(lo, lo + batch_rows));
        first.add_batch(&feats, &y.slice_rows(lo, lo + batch_rows));
    }
    let ck =
        TrainCheckpoint::capture(meta, spec.clone(), n as u64, batch_rows as u64, 1, &first);
    let ck = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
    assert_eq!(ck.meta.n_seen, 2 * batch_rows as u64);
    assert_eq!(ck.ckpt_every, 1);
    let mut resumed = ck.restore_regressor().unwrap();
    for lo in ((2 * batch_rows)..n).step_by(batch_rows) {
        let feats = f.transform(&x.slice_rows(lo, lo + batch_rows));
        resumed.add_batch(&feats, &y.slice_rows(lo, lo + batch_rows));
    }
    resumed.solve(1e-2).unwrap();
    assert_eq!(resumed.n_seen, full.n_seen);
    assert_bits_eq(
        &resumed.weights().unwrap().data,
        &full.weights().unwrap().data,
        "resumed vs uninterrupted weights",
    );
}

#[test]
fn corrupted_files_are_refused_with_readable_errors() {
    let spec = all_specs(5).remove(0);
    let (saved, _, _) = fit_tiny(&spec, 1, 51);
    let bytes = saved.to_bytes();
    assert!(SavedModel::from_bytes(&bytes).is_ok());

    // truncation at many prefixes: always Err, never panic
    for cut in [0, 1, 4, 15, 16, 40, bytes.len() / 2, bytes.len() - 1] {
        let err = SavedModel::from_bytes(&bytes[..cut]).unwrap_err();
        assert!(!err.to_string().is_empty(), "cut={cut}");
    }

    // flipped byte in a payload → CRC error naming the section
    let mut bad = bytes.clone();
    let last = bad.len() - 1;
    bad[last] ^= 0x40;
    let err = SavedModel::from_bytes(&bad).unwrap_err();
    assert!(err.to_string().contains("CRC"), "{err}");

    // bumped format version → clean refusal mentioning versions
    let mut bad = bytes.clone();
    bad[4] = 0x7F;
    bad[5] = 0x00;
    let err = SavedModel::from_bytes(&bad).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");

    // wrong magic → "not a model file"
    let mut bad = bytes.clone();
    bad[0] = b'X';
    let err = SavedModel::from_bytes(&bad).unwrap_err();
    assert!(err.to_string().contains("magic"), "{err}");
}

#[test]
fn golden_rows_catch_determinism_drift() {
    let spec = all_specs(6).remove(1);
    let (saved, _, _) = fit_tiny(&spec, 1, 61);
    let mut drifted = SavedModel::from_bytes(&saved.to_bytes()).unwrap();
    // simulate a seed/config drift: the stored spec no longer matches
    // the stored golden features
    if let FeaturizerSpec::NtkRf { seed, .. } = &mut drifted.spec {
        *seed ^= 1;
    } else {
        panic!("expected ntkrf spec");
    }
    // golden inputs are derived from the seed too; pin them to the
    // originals so only the featurizer draw changes
    drifted.golden_x = saved.golden_x.clone();
    let err = drifted.build().unwrap_err();
    assert!(err.to_string().contains("golden"), "{err}");
    assert!(err.to_string().contains("determinism"), "{err}");
}

#[test]
fn ntkrf_artifact_is_spec_sized_not_matrix_sized() {
    // the acceptance bar: a saved NTKRF model file is ≤1% of its
    // materialized random matrices (the weights blob is ridge W only)
    let spec = FeaturizerSpec::NtkRf {
        d: 32,
        depth: 2,
        m0: 512,
        m1: 1536,
        ms: 512,
        leverage_sweeps: 0,
        seed: 71,
    };
    let f = spec.build();
    let m = f.dim();
    let weights = Mat::zeros(m, 1);
    let saved =
        SavedModel::new("big", "synthetic", 71, 1e-3, 1000, spec.clone(), weights, &f);
    let file = saved.to_bytes().len() as u64;
    let materialized = spec.materialized_bytes();
    assert!(
        100 * file <= materialized,
        "file {file} B vs materialized {materialized} B (ratio {:.4})",
        file as f64 / materialized as f64
    );
}

#[test]
fn registry_versions_latest_and_gc() {
    let root = std::env::temp_dir().join(format!("ntkm_reg_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let registry = Registry::open(&root);

    let spec = all_specs(5).remove(0);
    let (saved, x, _) = fit_tiny(&spec, 1, 81);
    assert_eq!(registry.save(&saved).unwrap(), 1);
    assert_eq!(registry.save(&saved).unwrap(), 2);
    assert_eq!(registry.save(&saved).unwrap(), 3);

    let latest = registry.load("tiny", None).unwrap();
    assert_eq!(latest.meta.version, 3);
    let v1 = registry.load("tiny", Some(1)).unwrap();
    assert_eq!(v1.meta.version, 1);
    // same artifact content regardless of version
    assert_bits_eq(
        &latest.build().unwrap().predict(&x).data,
        &v1.build().unwrap().predict(&x).data,
        "versions",
    );

    let entries = registry.list();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].name, "tiny");
    assert_eq!(entries[0].versions, vec![1, 2, 3]);
    assert_eq!(entries[0].latest, Some(3));

    let removed = registry.gc("tiny", 1).unwrap();
    assert_eq!(removed, vec![1, 2]);
    assert!(registry.load("tiny", Some(1)).is_err());
    assert_eq!(registry.load("tiny", None).unwrap().meta.version, 3);

    // checkpoint lifecycle
    let reg0 = RidgeRegressor::new(spec.feature_dim(), 1);
    let meta = saved.meta.clone();
    let ck = TrainCheckpoint::capture(meta, spec, 40, 8, 1, &reg0);
    registry.save_checkpoint(&ck).unwrap();
    let (name, found) = registry.find_checkpoint(None).unwrap();
    assert_eq!(name, "tiny");
    assert_eq!(found.batch_rows, 8);
    registry.clear_checkpoint("tiny").unwrap();
    assert!(registry.find_checkpoint(None).is_err());

    // path-traversal names are rejected
    assert!(registry.load("../evil", None).is_err());
    assert!(registry.load("", None).is_err());

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn concurrent_saves_and_gc_never_corrupt_a_racing_load() {
    // Writers advance versions while a gc thread prunes and loaders spin
    // on `load(name, None)`. The store's contract under this race: every
    // load either resolves a COMPLETE version (golden-verified build,
    // bit-identical predictions) or fails with a readable not-found-style
    // error — never a CRC/magic/truncation error, which would mean a
    // loader observed a half-written or half-deleted artifact.
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    let root =
        std::env::temp_dir().join(format!("ntkm_reg_race_test_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let registry = Registry::open(&root);

    let spec = all_specs(5).remove(0);
    let (saved, x, _) = fit_tiny(&spec, 1, 91);
    assert_eq!(registry.save(&saved).unwrap(), 1);
    // every save stores the same artifact, so one reference prediction
    // checks any version a loader happens to resolve
    let reference = registry.load("tiny", None).unwrap().build().unwrap().predict(&x).data;

    let stop = Arc::new(AtomicBool::new(false));
    let good_loads = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();

    // writer: keeps advancing LATEST
    {
        let (root, saved, stop) = (root.clone(), saved.clone(), stop.clone());
        handles.push(std::thread::spawn(move || {
            let registry = Registry::open(&root);
            for _ in 0..24 {
                registry.save(&saved).expect("concurrent save");
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }));
    }
    // collector: prunes everything but the newest two, racing the loaders
    {
        let (root, stop) = (root.clone(), stop.clone());
        handles.push(std::thread::spawn(move || {
            let registry = Registry::open(&root);
            while !stop.load(Ordering::Relaxed) {
                let _ = registry.gc("tiny", 2);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        }));
    }
    // loaders: every resolved artifact must be complete and correct
    for _ in 0..2 {
        let (root, x, reference) = (root.clone(), x.clone(), reference.clone());
        let (stop, good_loads) = (stop.clone(), good_loads.clone());
        handles.push(std::thread::spawn(move || {
            let registry = Registry::open(&root);
            while !stop.load(Ordering::Relaxed) {
                match registry.load("tiny", None) {
                    Ok(loaded) => {
                        // build() golden-verifies: a torn artifact that
                        // somehow parsed would be refused here
                        let model = loaded.build().expect("resolved version must be complete");
                        assert_bits_eq(
                            &model.predict(&x).data,
                            &reference,
                            "racing load",
                        );
                        good_loads.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        // the only acceptable failure is the resolved
                        // version vanishing under gc between listing and
                        // reading — a clean not-found, never torn bytes
                        let msg = e.to_string();
                        assert!(
                            !msg.contains("CRC") && !msg.contains("magic"),
                            "racing load saw a corrupt artifact: {msg}"
                        );
                    }
                }
            }
        }));
    }

    // let the race run, then stop everyone
    std::thread::sleep(std::time::Duration::from_millis(250));
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().expect("race thread");
    }
    assert!(
        good_loads.load(Ordering::Relaxed) >= 1,
        "loaders never resolved a complete version"
    );
    let _ = std::fs::remove_dir_all(&root);
}
