//! Integration: the networked serving tier end to end (DESIGN.md §10).
//! Real registry-built models behind real TCP sockets:
//!  1. the networked session is bit-identical to the in-process
//!     [`DirectSession`] reference;
//!  2. hot swap under live traffic — every response is a complete,
//!     uncorrupted prediction from exactly one replica version;
//!  3. hostile wire bytes (bad magic, wrong version, oversized length,
//!     mid-frame disconnect) get typed errors and never kill the server
//!     or leak a connection slot;
//!  4. the SHUTDOWN frame stops a running daemon cleanly.

use ntk_sketch::model::{FeaturizerSpec, Registry, SavedModel};
use ntk_sketch::rng::Rng;
use ntk_sketch::serve::{
    read_frame, DirectSession, ErrorCode, Frame, InferenceSession, ServeOptions, TcpServer,
    TcpSession,
};
use ntk_sketch::tensor::Mat;
use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

const D: usize = 8;

/// A real spec-built model; the featurizer is pinned by a fixed spec
/// seed so two models differ only in their ridge weights.
fn saved_model(name: &str, weight_seed: u64) -> SavedModel {
    let spec = FeaturizerSpec::NtkRf {
        d: D,
        depth: 2,
        m0: 16,
        m1: 32,
        ms: 16,
        leverage_sweeps: 0,
        seed: 100,
    };
    let f = spec.build();
    let mut rng = Rng::new(weight_seed);
    let weights = Mat::from_vec(f.dim(), 1, rng.gauss_vec(f.dim()));
    SavedModel::new(name, "synthetic", weight_seed, 1e-3, 64, spec, weights, &f)
}

fn temp_root(tag: &str) -> PathBuf {
    let root = std::env::temp_dir().join(format!("ntk_serve_tier_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn batch(seed: u64, rows: usize) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(rows, D, rng.gauss_vec(rows * D))
}

#[test]
fn tcp_session_is_bit_identical_to_direct() {
    let saved = saved_model("parity", 1);
    let reference = Arc::new(saved.build().unwrap());
    let server = TcpServer::start(
        saved.build().unwrap(),
        None,
        "127.0.0.1:0",
        ServeOptions { workers: 2, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut tcp = TcpSession::connect(&addr).unwrap();
    let mut direct = DirectSession::new(reference);
    assert_eq!(tcp.input_dim(), D);
    assert_eq!(tcp.output_dim(), 1);
    for seed in 0..4 {
        let x = batch(10 + seed, 16);
        let via_tcp = tcp.infer(&x).unwrap();
        let via_direct = direct.infer(&x).unwrap();
        // bitwise, not approximate: the tier ships f32s losslessly
        assert_eq!(via_tcp.data, via_direct.data, "seed {seed}");
    }
    let stats = tcp.stats().unwrap();
    assert_eq!(stats.version, 1);
    assert!(stats.total.requests >= 4, "served requests show up in stats");
    drop(tcp);
    server.join();
}

#[test]
fn hot_swap_under_traffic_never_corrupts_a_response() {
    let root = temp_root("swap");
    let registry = Registry::open(&root);
    let v1 = saved_model("hs", 1);
    let v2 = saved_model("hs", 2);
    registry.save(&v1).unwrap();

    let serving = registry.load("hs", None).unwrap().build().unwrap();
    let server = TcpServer::start(
        serving,
        Some((Registry::open(&root), "hs".to_string())),
        "127.0.0.1:0",
        ServeOptions { workers: 2, poll_ms: 25, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let x = batch(77, 8);
    let pred1 = v1.build().unwrap().predict(&x).data;
    let pred2 = v2.build().unwrap().predict(&x).data;
    assert_ne!(pred1, pred2, "the two versions must be distinguishable");

    let mut sess = TcpSession::connect(&addr).unwrap();
    for _ in 0..10 {
        assert_eq!(sess.infer(&x).unwrap().data, pred1);
    }

    // advance LATEST while traffic keeps flowing; every response must be
    // exactly one version's prediction — a torn or partial swap would
    // produce something that matches neither
    registry.save(&v2).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let out = sess.infer(&x).unwrap().data;
        if out == pred2 {
            break;
        }
        assert_eq!(out, pred1, "response matches neither replica version");
        assert!(Instant::now() < deadline, "hot swap never observed");
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = sess.stats().unwrap();
    assert!(stats.swaps >= 1, "swap counter advanced");
    assert_eq!(stats.version, 2);
    drop(sess);
    server.join();
    let _ = std::fs::remove_dir_all(&root);
}

/// Handcraft a 16-byte frame header (magic, version, kind, id, len).
fn header(magic: &[u8; 2], version: u8, kind: u8, id: u64, len: u32) -> [u8; 16] {
    let mut h = [0u8; 16];
    h[0..2].copy_from_slice(magic);
    h[2] = version;
    h[3] = kind;
    h[4..12].copy_from_slice(&id.to_le_bytes());
    h[12..16].copy_from_slice(&len.to_le_bytes());
    h
}

/// Open a raw connection, consume the HELLO, send `bytes`, and return
/// the server's next client-bound frame (None on close).
fn poke(addr: &str, bytes: &[u8]) -> Option<Frame> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let hello = read_frame(&mut reader).unwrap();
    assert!(matches!(hello, Frame::Hello { .. }), "expected HELLO, got {hello:?}");
    let mut writer = stream;
    writer.write_all(bytes).unwrap();
    read_frame(&mut reader).ok()
}

#[test]
fn hostile_bytes_get_typed_errors_and_leak_nothing() {
    let saved = saved_model("hostile", 1);
    // max_conns = 2: if any hostile connection leaked its slot, the
    // final healthy session below could not be admitted
    let server = TcpServer::start(
        saved.build().unwrap(),
        None,
        "127.0.0.1:0",
        ServeOptions { workers: 1, max_conns: 2, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    // bad magic → typed protocol error, then close
    match poke(&addr, b"XXXXXXXXXXXXXXXX") {
        Some(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Protocol),
        other => panic!("bad magic: expected a Protocol error frame, got {other:?}"),
    }

    // wrong protocol version → typed protocol error
    match poke(&addr, &header(b"NW", 9, 2, 0, 0)) {
        Some(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Protocol),
        other => panic!("wrong version: expected a Protocol error frame, got {other:?}"),
    }

    // oversized length prefix → refused before any allocation
    match poke(&addr, &header(b"NW", 1, 2, 0, (1 << 24) + 1)) {
        Some(Frame::Error { code, .. }) => assert_eq!(code, ErrorCode::Protocol),
        other => panic!("oversized len: expected a Protocol error frame, got {other:?}"),
    }

    // shape-lying payload: header promises more rows than bytes sent,
    // then the peer disconnects mid-frame — the server must just drop
    // the connection, not wait forever or panic
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
        let hello = read_frame(&mut reader).unwrap();
        assert!(matches!(hello, Frame::Hello { .. }));
        let mut writer = stream;
        writer.write_all(&header(b"NW", 1, 2, 0, 1000)).unwrap();
        writer.write_all(&[0u8; 10]).unwrap();
        // drop both halves: mid-frame disconnect
    }

    // after all of the above the server still serves, and both hostile
    // slots have been released (max_conns = 2 admits us)
    let ok = (0..50).find_map(|_| {
        std::thread::sleep(Duration::from_millis(20));
        TcpSession::connect(&addr).ok()
    });
    let mut sess = ok.expect("server admits a healthy session after hostile peers");
    let out = sess.infer(&batch(5, 4)).unwrap();
    assert_eq!((out.rows, out.cols), (4, 1));
    drop(sess);
    server.join();
}

#[test]
fn stalled_mid_header_peer_is_reaped_and_frees_its_conn_slot() {
    let saved = saved_model("stall", 1);
    // max_conns = 1: the stalled peer holds the ONLY slot, so the healthy
    // session below can connect only if the server reaps the staller
    let server = TcpServer::start(
        saved.build().unwrap(),
        None,
        "127.0.0.1:0",
        ServeOptions { workers: 1, max_conns: 1, stall_ms: 300, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    // connect, consume HELLO, send 7 of the 16 header bytes, then stall
    // with the socket held open — a mid-frame stall, not a disconnect
    let staller = TcpStream::connect(&addr).unwrap();
    let mut reader = std::io::BufReader::new(staller.try_clone().unwrap());
    let hello = read_frame(&mut reader).unwrap();
    assert!(matches!(hello, Frame::Hello { .. }));
    let mut writer = staller.try_clone().unwrap();
    writer.write_all(&header(b"NW", 1, 2, 0, 0)[..7]).unwrap();
    writer.flush().unwrap();

    // the server's mid-frame deadline (stall_ms) must fire, disconnect
    // the staller, and release the slot — all while the socket stays open
    let ok = (0..200).find_map(|_| {
        std::thread::sleep(Duration::from_millis(25));
        TcpSession::connect(&addr).ok()
    });
    let mut sess =
        ok.expect("stalled peer still holds the only conn slot after the deadline");
    let out = sess.infer(&batch(9, 4)).unwrap();
    assert_eq!((out.rows, out.cols), (4, 1));
    drop(sess);
    drop(staller);
    server.join();
}

#[test]
fn metrics_frame_reconciles_with_client_observed_traffic() {
    use ntk_sketch::obs::{parse_prometheus, prom_value};

    let saved = saved_model("metrics", 1);
    let server = TcpServer::start(
        saved.build().unwrap(),
        None,
        "127.0.0.1:0",
        ServeOptions { workers: 2, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();

    let mut sess = TcpSession::connect(&addr).unwrap();
    let (mut sent, mut rows_sent) = (0u64, 0u64);
    for seed in 0..12u64 {
        let rows = 1 + (seed as usize % 5);
        let out = sess.infer(&batch(200 + seed, rows)).unwrap();
        assert_eq!(out.rows, rows);
        sent += 1;
        rows_sent += rows as u64;
    }
    let text = sess.metrics().unwrap();
    let samples = parse_prometheus(&text);

    // counters reconcile exactly with what this client observed
    assert_eq!(prom_value(&samples, "ntk_requests_total"), Some(sent as f64), "{text}");
    assert_eq!(prom_value(&samples, "ntk_rows_total"), Some(rows_sent as f64));
    assert_eq!(prom_value(&samples, "ntk_rejected_total"), Some(0.0));
    assert_eq!(prom_value(&samples, "ntk_panics_total"), Some(0.0));
    assert_eq!(prom_value(&samples, "ntk_model_version"), Some(1.0));

    // the request-latency histogram saw exactly `sent` observations, and
    // its cumulative +Inf bucket agrees with its _count
    assert_eq!(prom_value(&samples, "ntk_request_latency_us_count"), Some(sent as f64));
    assert_eq!(
        prom_value(&samples, "ntk_request_latency_us_bucket{le=\"+Inf\"}"),
        Some(sent as f64)
    );

    // per-shard series sum to the fleet total (exact bucket-wise merge)
    let shard_sum: f64 = (0..2)
        .map(|i| {
            prom_value(&samples, &format!("ntk_requests_total{{shard=\"{i}\"}}")).unwrap_or(0.0)
        })
        .sum();
    assert_eq!(shard_sum, sent as f64, "shard series must sum to the fleet counter");

    drop(sess);
    server.join();
}

#[test]
fn shutdown_frame_stops_a_running_daemon() {
    let saved = saved_model("shutdown", 1);
    let server = TcpServer::start(
        saved.build().unwrap(),
        None,
        "127.0.0.1:0",
        ServeOptions { workers: 1, ..ServeOptions::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let daemon = std::thread::spawn(move || server.run_until_shutdown());

    let mut sess = TcpSession::connect(&addr).unwrap();
    let out = sess.infer(&batch(3, 2)).unwrap();
    assert_eq!(out.rows, 2);
    sess.shutdown_server().unwrap();
    drop(sess);
    daemon.join().expect("daemon exits after the shutdown frame");
}
