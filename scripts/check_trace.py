#!/usr/bin/env python3
"""Validate an NTK_TRACE capture and require stage coverage.

Checks that the file is Chrome trace-event JSON of the shape documented
in DESIGN.md section 12 — a ``traceEvents`` array of complete-phase
(``"ph": "X"``) events each carrying name/pid/tid/ts/dur — and that every
stage named on the command line appears at least once. CI runs this over
a capture taken from a real ``train --save`` run, so a span that silently
stops firing (or a rename that breaks the documented taxonomy) fails the
build.

Usage: check_trace.py <trace.json> <required-stage> [<required-stage>...]
"""

import json
import sys


def main():
    if len(sys.argv) < 3:
        print(__doc__)
        return 2
    path, required = sys.argv[1], sys.argv[2:]
    with open(path) as f:
        doc = json.load(f)

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        print(f"{path}: `traceEvents` missing or empty")
        return 1

    seen = {}
    for i, e in enumerate(events):
        for key, typ in (
            ("name", str),
            ("ph", str),
            ("cat", str),
            ("pid", (int, float)),
            ("tid", (int, float)),
            ("ts", (int, float)),
            ("dur", (int, float)),
        ):
            if not isinstance(e.get(key), typ):
                print(f"{path}: event {i} field `{key}` missing or mistyped: {e}")
                return 1
        if e["ph"] == "X":
            seen[e["name"]] = seen.get(e["name"], 0) + 1

    missing = [s for s in required if s not in seen]
    for name in sorted(seen):
        print(f"  {name}: {seen[name]} span(s)")
    if missing:
        print(f"FAIL: {path} has no spans for: {', '.join(missing)}")
        return 1
    print(f"ok: {len(events)} events, all {len(required)} required stages present")
    return 0


if __name__ == "__main__":
    sys.exit(main())
