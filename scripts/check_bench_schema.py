#!/usr/bin/env python3
"""Validate machine-readable bench records against checked-in schemas.

Every ``BENCH_*.json`` the bench binaries emit is consumed downstream —
regression gates, the perf-trajectory history, the obs overhead gate — so
a bench that silently drops or renames a field must fail CI here, not
corrupt the trajectory three PRs later. Schemas are declarative specs of
required fields: ``float``/``str``/``bool`` leaves, ``[subschema]`` for
arrays of objects (validated element-wise, at least one element), and
nested dicts for sections. Extra fields are allowed — adding telemetry is
not a break; removing it is.

Usage: check_bench_schema.py <BENCH_x.json> [<BENCH_y.json>...]
       (each file is matched to a schema by basename)
"""

import json
import os
import sys

FLOAT = float
STR = str
BOOL = bool

SCHEMAS = {
    "BENCH_gemm.json": {
        "bench": STR,
        "smoke": BOOL,
        "full_scale": BOOL,
        "threads": FLOAT,
        "active_kernel": STR,
        "shapes": [
            {
                "name": STR,
                "m": FLOAT,
                "n": FLOAT,
                "k": FLOAT,
                "gflops_packed": FLOAT,
                "gflops_seed": FLOAT,
                "speedup": FLOAT,
            }
        ],
    },
    "BENCH_cntk.json": {
        "bench": STR,
        "smoke": BOOL,
        "threads": FLOAT,
        "depth": FLOAT,
        "q": FLOAT,
        "s_out": FLOAT,
        "sizes": [
            {
                "side": FLOAT,
                "pixels": FLOAT,
                "sketch_us_per_image": FLOAT,
                "exact_us_per_pair": FLOAT,
                "pair_speedup": FLOAT,
                "gram_speedup_n1000": FLOAT,
            }
        ],
    },
    "BENCH_model_store.json": {
        "save_ms": FLOAT,
        "load_verify_ms": FLOAT,
        "first_predict_ms": FLOAT,
        "first_served_ms": FLOAT,
        "file_bytes": FLOAT,
        "materialized_bytes": FLOAT,
        "feature_dim": FLOAT,
    },
    "BENCH_solver.json": {
        "bench": STR,
        "smoke": BOOL,
        "threads": FLOAT,
        "auto_threshold_m": FLOAT,
        "sizes": [
            {
                "m": FLOAT,
                "chol_ms": FLOAT,
                "pcg_ms": FLOAT,
                "pcg_iters": FLOAT,
                "precond_rank": FLOAT,
                "pcg_wins": BOOL,
                "speedup": FLOAT,
            }
        ],
        "crossover_m": FLOAT,
        "pcg_wins_at_largest": BOOL,
    },
    "BENCH_serve.json": {
        "clients": FLOAT,
        "rows_per_request": FLOAT,
        "secs_per_config": FLOAT,
        "configs": [
            {
                "workers": FLOAT,
                "qps": FLOAT,
                "p50_us": FLOAT,
                "p99_us": FLOAT,
                "ok": FLOAT,
                "rejected": FLOAT,
            }
        ],
        "tracing_overhead": {
            "span_disabled_ns": FLOAT,
            "spans_per_request": FLOAT,
            "qps_disabled": FLOAT,
            "qps_enabled": FLOAT,
            "disabled_overhead_pct": FLOAT,
            "enabled_overhead_pct": FLOAT,
        },
    },
}


def check(value, schema, path, errors):
    if schema is FLOAT:
        if not isinstance(value, (int, float)) or isinstance(value, bool):
            errors.append(f"{path}: expected a number, got {value!r}")
    elif schema is STR:
        if not isinstance(value, str):
            errors.append(f"{path}: expected a string, got {value!r}")
    elif schema is BOOL:
        if not isinstance(value, bool):
            errors.append(f"{path}: expected a bool, got {value!r}")
    elif isinstance(schema, list):
        if not isinstance(value, list):
            errors.append(f"{path}: expected an array, got {value!r}")
        elif not value:
            errors.append(f"{path}: array is empty")
        else:
            for i, item in enumerate(value):
                check(item, schema[0], f"{path}[{i}]", errors)
    elif isinstance(schema, dict):
        if not isinstance(value, dict):
            errors.append(f"{path}: expected an object, got {value!r}")
            return
        for key, sub in schema.items():
            if key not in value:
                errors.append(f"{path}.{key}: required field missing")
            else:
                check(value[key], sub, f"{path}.{key}", errors)
    else:  # pragma: no cover - schema author error
        raise AssertionError(f"bad schema node at {path}")


def main():
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    failures = 0
    for path in sys.argv[1:]:
        base = os.path.basename(path)
        schema = SCHEMAS.get(base)
        if schema is None:
            print(f"{path}: no schema registered for `{base}` — add one to "
                  f"{os.path.basename(__file__)} alongside the new bench")
            failures += 1
            continue
        with open(path) as f:
            doc = json.load(f)
        errors = []
        check(doc, schema, base, errors)
        if errors:
            failures += 1
            for e in errors:
                print(e)
        else:
            print(f"{base}: ok")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
