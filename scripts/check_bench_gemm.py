#!/usr/bin/env python3
"""Gate GEMM bench regressions against the committed baseline.

Compares the per-shape ``speedup`` (packed engine vs the frozen seed
loops, measured in the same process on the same machine — so the ratio
is machine-portable even though raw GFLOP/s are not) from a freshly
produced ``BENCH_gemm.json`` against ``rust/BENCH_gemm_baseline.json``.
A shape regresses when its speedup falls more than TOLERANCE below the
baseline floor. Exits non-zero listing every regression.

Baseline floors are deliberately conservative (well under what the
engine actually delivers) so the gate catches "someone broke the packed
path / the pool / the dispatch" — not benchmark noise or a slower CI
runner.

Usage: check_bench_gemm.py <current BENCH_gemm.json> [baseline.json]
"""

import json
import os
import sys

TOLERANCE = 0.20  # allow 20% under the baseline floor before failing


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    current = load(sys.argv[1])
    baseline_path = (
        sys.argv[2]
        if len(sys.argv) > 2
        else os.path.join(
            os.path.dirname(__file__), "..", "rust", "BENCH_gemm_baseline.json"
        )
    )
    baseline = load(baseline_path)

    cur_shapes = {s["name"]: s for s in current.get("shapes", [])}
    failures = []
    for base in baseline["shapes"]:
        name = base["name"]
        if name not in cur_shapes:
            failures.append(f"{name}: missing from current bench output")
            continue
        floor = base["speedup"] * (1.0 - TOLERANCE)
        got = cur_shapes[name]["speedup"]
        status = "ok" if got >= floor else "REGRESSED"
        print(
            f"{name:>14}: speedup {got:6.2f}x  "
            f"(floor {floor:.2f}x = baseline {base['speedup']:.2f}x - {TOLERANCE:.0%})  {status}"
        )
        if got < floor:
            failures.append(
                f"{name}: speedup {got:.2f}x < floor {floor:.2f}x"
            )

    # informational only: SIMD-vs-portable ratio is hardware-dependent
    # (a CI runner without AVX2 legitimately reports nothing here), so it
    # is printed but never gated.
    ratio = current.get("simd_vs_portable")
    if ratio is not None:
        print(f"simd_vs_portable: {ratio:.2f}x (active: {current.get('active_kernel')})")

    if failures:
        print("\nGEMM bench regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nGEMM bench regression gate passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
