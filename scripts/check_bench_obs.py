#!/usr/bin/env python3
"""Gate the cost of disabled tracing spans on the serve path.

Reads the ``tracing_overhead`` section of ``BENCH_serve.json`` and fails
when ``disabled_overhead_pct`` — per-span disabled cost x spans per
request / mean request latency, measured in the same process — exceeds
the budget. The analytic definition is deliberate: it is stable where a
raw QPS delta between two short closed-loop runs is noise, so the gate
catches "someone put real work on the disabled span path" and nothing
else. The enabled-mode QPS delta is printed for context but not gated.

Usage: check_bench_obs.py <BENCH_serve.json>
"""

import json
import sys

MAX_DISABLED_OVERHEAD_PCT = 1.0


def main():
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    with open(sys.argv[1]) as f:
        bench = json.load(f)

    oh = bench.get("tracing_overhead")
    if oh is None:
        print(f"{sys.argv[1]}: missing `tracing_overhead` section")
        return 1
    for key in (
        "span_disabled_ns",
        "spans_per_request",
        "qps_disabled",
        "qps_enabled",
        "disabled_overhead_pct",
        "enabled_overhead_pct",
    ):
        if not isinstance(oh.get(key), (int, float)):
            print(f"tracing_overhead.{key}: missing or not a number")
            return 1

    pct = oh["disabled_overhead_pct"]
    print(
        f"disabled span: {oh['span_disabled_ns']:.1f}ns/call x "
        f"{oh['spans_per_request']:.0f} spans/request = {pct:.4f}% overhead "
        f"(budget {MAX_DISABLED_OVERHEAD_PCT}%)"
    )
    print(
        f"qps disabled={oh['qps_disabled']:.0f} enabled={oh['qps_enabled']:.0f} "
        f"(enabled overhead {oh['enabled_overhead_pct']:+.1f}%, informational)"
    )
    if pct > MAX_DISABLED_OVERHEAD_PCT:
        print(
            f"FAIL: disabled-mode tracing overhead {pct:.4f}% exceeds "
            f"{MAX_DISABLED_OVERHEAD_PCT}% — the span fast path must stay "
            f"one relaxed atomic load"
        )
        return 1
    print("ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
