//! Fig. 2b / Table 1 (scaled): CIFAR-like classification with CNTKSketch
//! vs GradRF(CNN), plus exact-CNTK timing on a small subset to
//! extrapolate the paper's 150× headline.
//!
//! Run: `cargo run --release --example cifar_cntk [--n 600 --side 10 --dim 512]`

use ntk_sketch::cntk::exact::CntkExact;
use ntk_sketch::data::{cifar_like, split};
use ntk_sketch::features::cntk_sketch::{CntkSketch, CntkSketchConfig};
use ntk_sketch::features::grad_rf::GradRfCnn;
use ntk_sketch::features::ImageFeaturizer;
use ntk_sketch::regression::cv::{lambda_grid, select_lambda_classification};
use ntk_sketch::regression::{accuracy, RidgeRegressor};
use ntk_sketch::rng::Rng;
use ntk_sketch::util::cli::Args;
use ntk_sketch::util::timer::{fmt_secs, timed, Timer};

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 600);
    let side = args.usize("side", 10);
    let dim = args.usize("dim", 512);
    let depth = args.usize("depth", 3); // paper: conv depth L = 3
    let q = 3;
    let mut rng = Rng::new(args.u64("seed", 2));

    let ds = cifar_like::generate(n, side, 21);
    let (train0, test) = split::train_test_images(&ds, 0.2, 22);
    let (train, val) = split::train_test_images(&train0, 0.15, 23);
    println!(
        "cifar-like: train={} val={} test={} {}x{}x3  depth={depth} q={q} budget={dim}",
        train.n(),
        val.n(),
        test.n(),
        side,
        side
    );

    let labels = |ds: &ntk_sketch::data::ImageDataset| -> Vec<f32> {
        ds.labels.iter().map(|&l| l as f32).collect()
    };
    let one_hot = |ds: &ntk_sketch::data::ImageDataset| ds.one_hot_centered();

    println!("{:<16} {:>9} {:>10} {:>12}", "method", "dim", "test acc", "featurize");
    let featurizers: Vec<(&str, Box<dyn ImageFeaturizer>)> = vec![
        (
            "GradRF(CNN)",
            Box::new(GradRfCnn::for_feature_dim(side, side, 3, depth, q, dim, &mut rng)),
        ),
        (
            "CNTKSketch",
            Box::new(CntkSketch::new(
                side,
                side,
                3,
                CntkSketchConfig::for_budget(depth.max(2), q, dim),
                &mut rng,
            )),
        ),
    ];
    for (name, f) in featurizers {
        let (blocks, t_feat) = timed(|| {
            (
                f.transform_images(&train.images),
                f.transform_images(&val.images),
                f.transform_images(&test.images),
            )
        });
        let (ftr, fval, fte) = blocks;
        let (lam, _) = select_lambda_classification(
            &ftr,
            &one_hot(&train),
            &fval,
            &labels(&val),
            &lambda_grid(),
        );
        let r = RidgeRegressor::fit(&ftr, &one_hot(&train), lam).unwrap();
        let acc = accuracy(&r.predict(&fte), &labels(&test));
        println!("{:<16} {:>9} {:>9.1}% {:>12}", name, f.dim(), 100.0 * acc, fmt_secs(t_feat));
    }

    // exact CNTK cost: time a small k×k Gram block, extrapolate to full n²
    let k = args.usize("exact-sample", 8).min(train.n());
    let cntk = CntkExact::new(depth.max(2), q);
    let sub: Vec<_> = train.images[..k].to_vec();
    let t = Timer::start();
    let _ = cntk.gram(&sub);
    let per_pair = t.secs() / ((k * (k + 1)) as f64 / 2.0);
    let full_pairs = (n * (n + 1)) as f64 / 2.0;
    println!(
        "\nexact CNTK: {:.2}ms/pair measured on {k} images ⇒ full {n}-image Gram ≈ {}",
        1e3 * per_pair,
        fmt_secs(per_pair * full_pairs)
    );
    println!("(Table 1's point: this quadratic cost is what CNTKSketch's linear-in-pixels feature map replaces)");
}
