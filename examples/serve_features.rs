//! End-to-end system driver (DESIGN.md §6): the full three-layer stack on
//! a real workload.
//!
//!   L1/L2: the AOT artifact (`make artifacts`) — NTKRF in jax over the
//!          Pallas kernels, lowered to HLO text;
//!   runtime: PJRT CPU client executes it with device-resident weights;
//!   L3: the FeatureServer batches concurrent requests (size/deadline
//!       policy) and the streaming ridge accumulates normal equations.
//!
//! Trains on a UCI-like regression stream via the serving path and then
//! serves a closed-loop latency/throughput benchmark. Results are
//! recorded in EXPERIMENTS.md.
//!
//! Without the `pjrt` feature (or with `--store`) it drives the model
//! store instead (DESIGN.md §8): streaming fit with checkpoints → save
//! to a registry → reload in-process through the golden-row check →
//! serve *predictions* from the durable model via `NativeBackend`.
//!
//! Run: `make artifacts && cargo run --release --example serve_features`

use ntk_sketch::coordinator::{BatchBackend, BatchPolicy, FeatureServer, Metrics, NativeBackend};
use ntk_sketch::data::uci_like::{generate, UciFamily};
use ntk_sketch::model::{FeaturizerSpec, ModelMeta, Registry, SavedModel, TrainCheckpoint};
use ntk_sketch::regression::{mse, RidgeRegressor};
use ntk_sketch::runtime::{artifacts_dir, Engine};
use ntk_sketch::tensor::Mat;
use ntk_sketch::util::cli::Args;
use ntk_sketch::util::timer::Timer;

struct PjrtBackend {
    engine: Engine,
}

impl BatchBackend for PjrtBackend {
    fn batch(&self) -> usize {
        self.engine.batch()
    }
    fn input_dim(&self) -> usize {
        self.engine.input_dim()
    }
    fn feature_dim(&self) -> usize {
        self.engine.feature_dim()
    }
    fn run(&self, x: &Mat) -> Mat {
        self.engine.run_batch(x).expect("pjrt batch")
    }
}

/// The store-backed driver: the whole model lifecycle in one process,
/// ending with the coordinator serving predictions from a model that
/// went through disk.
fn store_demo(args: &Args) {
    let root = std::env::var_os("NTK_MODEL_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::env::temp_dir().join(format!("ntk_serve_features_{}", std::process::id()))
        });
    let registry = Registry::open(&root);
    let fam = UciFamily::MillionSongs;
    let n_train = args.usize("n", 1024);
    let n_test = 256;
    let ds = generate(fam, n_train + n_test, 61);
    let spec = FeaturizerSpec::NtkRf {
        d: ds.d(),
        depth: 2,
        m0: 128,
        m1: 384,
        ms: 128,
        leverage_sweeps: 0,
        seed: 77,
    };
    let f = spec.build();
    let meta = ModelMeta {
        name: "serve-demo".into(),
        version: 0,
        family: spec.family().into(),
        dataset: fam.name().into(),
        data_seed: 61,
        lambda: args.f64("lambda", 1e-3),
        n_seen: 0,
        input_dim: spec.input_dim(),
        feature_dim: spec.feature_dim(),
        outputs: 1,
    };

    // ---- phase 1: streaming fit with periodic checkpoints ----
    let t_train = Timer::start();
    let y = ds.y_mat();
    let mut reg = RidgeRegressor::new(spec.feature_dim(), 1);
    let batch_rows = 128;
    let mut batches = 0usize;
    let mut lo = 0;
    while lo < n_train {
        let hi = (lo + batch_rows).min(n_train);
        let feats = f.transform(&ds.x.slice_rows(lo, hi));
        reg.add_batch(&feats, &y.slice_rows(lo, hi));
        batches += 1;
        lo = hi;
        if batches % 2 == 0 && lo < n_train {
            let ck = TrainCheckpoint::capture(
                meta.clone(),
                spec.clone(),
                n_train as u64,
                batch_rows as u64,
                2,
                &reg,
            );
            registry.save_checkpoint(&ck).expect("checkpoint");
        }
    }
    reg.solve(meta.lambda).expect("solve");
    let saved = SavedModel::new(
        "serve-demo",
        fam.name(),
        61,
        meta.lambda,
        reg.n_seen as u64,
        spec.clone(),
        reg.weights().expect("solved").clone(),
        &f,
    );
    let version = registry.save(&saved).expect("registry save");
    registry.clear_checkpoint("serve-demo").expect("clear checkpoint");
    let file_bytes = std::fs::metadata(registry.artifact_path("serve-demo", version))
        .map(|m| m.len())
        .unwrap_or(0);
    println!(
        "streaming fit: {n_train} rows in {:.2}s; saved v{version} ({file_bytes} bytes vs ≈{} \
         bytes of materialized featurizer)",
        t_train.secs(),
        spec.materialized_bytes()
    );

    // ---- phase 2: reload from disk (golden-row verified) and serve ----
    let loaded = registry.load("serve-demo", None).expect("registry load");
    let model = std::sync::Arc::new(loaded.build().expect("golden-verified build"));
    println!("loaded {}", model.meta.banner());
    let d = model.meta.input_dim;
    let m2 = model.clone();
    let (server, client) = FeatureServer::start(
        move || NativeBackend { featurizer: m2.clone(), batch: 64, input_dim: d },
        args.usize("workers", 2),
        BatchPolicy { max_batch: 64, max_delay: std::time::Duration::from_millis(2) },
        32,
    );
    let t_serve = Timer::start();
    let rxs: Vec<_> = (n_train..n_train + n_test)
        .map(|i| client.submit_row(ds.x.row(i).to_vec()).expect("submit"))
        .collect();
    let mut pred = Mat::zeros(n_test, 1);
    for (k, rx) in rxs.into_iter().enumerate() {
        pred.row_mut(k).copy_from_slice(&rx.recv().expect("prediction"));
    }
    let test_mse = mse(&pred, &y.slice_rows(n_train, n_train + n_test));
    println!(
        "served {n_test} predictions from the durable model in {:.2}s (test MSE {test_mse:.4})",
        t_serve.secs()
    );
    println!("metrics: {}", server.metrics.snapshot().summary());
    drop(client);
    server.join();
    if std::env::var_os("NTK_MODEL_DIR").is_none() {
        let _ = std::fs::remove_dir_all(&root);
    }
}

fn main() {
    let args = Args::from_env();
    let dir = artifacts_dir();
    if !ntk_sketch::runtime::pjrt_enabled() || args.flag("store") {
        println!("serve_features: model-store path (see DESIGN.md §8)");
        store_demo(&args);
        return;
    }
    if !dir.join("ntk_rf.manifest.json").exists() {
        // pjrt build without artifacts is a real failure, not a skip
        eprintln!("serve_features: artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let probe = Engine::load(&dir, "ntk_rf").expect("load artifact");
    let (d, fdim, batch) = (probe.input_dim(), probe.feature_dim(), probe.batch());
    println!(
        "artifact ntk_rf: depth={} d={d} feature_dim={fdim} batch={batch} (golden max rel err {:.1e})",
        probe.artifact.depth,
        probe.verify_golden(1e-3, 1e-4).expect("golden")
    );
    drop(probe);

    // ---- phase 1: streaming training through the serving path ----
    let n_train = args.usize("n", 2048);
    let n_test = 512;
    // project the uci-like inputs to the artifact's d
    let ds = generate(UciFamily::MillionSongs, n_train + n_test, 61);
    let proj = {
        let mut rng = ntk_sketch::rng::Rng::new(62);
        Mat::from_vec(ds.d(), d, rng.gauss_vec(ds.d() * d))
    };
    let x_all = ds.x.matmul(&proj);
    let x_train = x_all.slice_rows(0, n_train);
    let x_test = x_all.slice_rows(n_train, n_train + n_test);
    let y_train = Mat::from_vec(n_train, 1, ds.y[..n_train].to_vec());
    let y_test = Mat::from_vec(n_test, 1, ds.y[n_train..].to_vec());

    let dir2 = dir.clone();
    let (server, client) = FeatureServer::start(
        move || PjrtBackend { engine: Engine::load(&dir2, "ntk_rf").expect("engine") },
        args.usize("workers", 1),
        BatchPolicy { max_batch: batch, max_delay: std::time::Duration::from_millis(2) },
        32,
    );

    let t_train = Timer::start();
    let mut reg = RidgeRegressor::new(fdim, 1);
    // stream rows through the server in flight-controlled waves
    let wave = 256;
    let mut test_feats = Mat::zeros(n_test, fdim);
    {
        let mut lo = 0;
        while lo < n_train {
            let hi = (lo + wave).min(n_train);
            let rxs: Vec<_> =
                (lo..hi).map(|i| client.submit_row(x_train.row(i).to_vec()).unwrap()).collect();
            let mut feats = Mat::zeros(hi - lo, fdim);
            for (k, rx) in rxs.into_iter().enumerate() {
                feats.row_mut(k).copy_from_slice(&rx.recv().expect("feature row"));
            }
            reg.add_batch(&feats, &y_train.slice_rows(lo, hi));
            lo = hi;
        }
        // featurize the test set through the same path
        let rxs: Vec<_> =
            (0..n_test).map(|i| client.submit_row(x_test.row(i).to_vec()).unwrap()).collect();
        for (k, rx) in rxs.into_iter().enumerate() {
            test_feats.row_mut(k).copy_from_slice(&rx.recv().expect("feature row"));
        }
    }
    reg.solve(args.f64("lambda", 1e-3)).unwrap();
    let train_secs = t_train.secs();
    let test_mse = mse(&reg.predict(&test_feats), &y_test);
    let var: f64 =
        y_test.data.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / n_test as f64;
    println!(
        "\nstreaming training: {n_train} rows in {train_secs:.2}s ({:.0} rows/s), test MSE {test_mse:.4} (target var {var:.4})",
        n_train as f64 / train_secs
    );

    // ---- phase 2: closed-loop serving benchmark ----
    let n_req = args.usize("requests", 2000);
    let clients = args.usize("clients", 8);
    let t_serve = Timer::start();
    std::thread::scope(|s| {
        for c in 0..clients {
            let cl = client.clone();
            let x = &x_train;
            s.spawn(move || {
                let mut rng = ntk_sketch::rng::Rng::new(900 + c as u64);
                for _ in 0..n_req / clients {
                    let i = rng.below(x.rows);
                    let _ = cl.featurize(x.row(i).to_vec()).unwrap();
                }
            });
        }
    });
    let serve_secs = t_serve.secs();
    println!(
        "\nserving: {n_req} requests from {clients} closed-loop clients in {serve_secs:.2}s = {:.0} req/s",
        n_req as f64 / serve_secs
    );
    println!("metrics: {}", server.metrics.snapshot().summary());
    println!(
        "batch fill: {:.1}% (pad rows / (batches × {batch}))",
        100.0
            * (1.0
                - Metrics::get(&server.metrics.pad_rows) as f64
                    / (Metrics::get(&server.metrics.batches) as f64 * batch as f64))
    );
    drop(client);
    server.join();
}
