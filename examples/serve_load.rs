//! Closed-loop load generator for the networked serving tier
//! (DESIGN.md §10). Connects `--clients` concurrent retrying sessions to
//! a running `serve --listen` daemon and hammers it for `--min-secs`,
//! checking four properties the tier promises:
//!
//! 1. **No corruption**: each client cycles a fixed pool of request
//!    batches and pins the first response it sees per batch; every later
//!    response for the same batch must be bitwise identical. Because
//!    retraining with identical parameters is deterministic, this also
//!    holds *across a hot swap* — which is exactly how CI uses it
//!    (swap `LATEST` mid-run, assert zero mismatches).
//! 2. **No drops**: every admitted request gets exactly one response
//!    (the session API enforces ordering; a missing response would hang
//!    the closed loop and trip the wall-clock guard).
//! 3. **Typed backpressure**: saturation surfaces as
//!    `InferenceError::Rejected` with a retry hint, never a desync or a
//!    protocol error; the [`RetryingClient`] honors the hint.
//! 4. **Self-healing under chaos**: against a daemon running with
//!    `NTK_FAULTS` set, injected wire faults and shard panics surface as
//!    typed errors the retry policy absorbs — a resubmitted batch is
//!    bit-identical because inference is pure. Mismatch counting is
//!    unchanged, so this doubles as the chaos-mode corruption oracle.
//!
//! Exits nonzero on any mismatch or an exhausted retry budget, so shell
//! drivers can gate on it directly.
//!
//! Run: `ntk-sketch serve --model m1 --listen 127.0.0.1:7071 &`
//!      `cargo run --release --example serve_load -- --connect 127.0.0.1:7071`

use ntk_sketch::rng::Rng;
use ntk_sketch::serve::{InferenceSession, RetryPolicy, RetryingClient};
use ntk_sketch::tensor::Mat;
use ntk_sketch::util::cli::Args;
use std::time::Instant;

struct ClientStats {
    ok: u64,
    rejected: u64,
    reconnects: u64,
    mismatches: u64,
}

fn main() {
    let args = Args::from_env();
    let addr = match args.get("connect") {
        Some(a) => a.to_string(),
        None => {
            eprintln!("serve_load: needs --connect HOST:PORT (a running `serve --listen` daemon)");
            std::process::exit(2);
        }
    };
    let clients = args.usize("clients", 4).max(1);
    let min_secs = args.f64("min-secs", 5.0);
    let batch_rows = args.usize("rows", 8).max(1);
    let pool = args.usize("pool", 32).max(1);
    let retries = args.usize("retries", 16).max(1) as u32;

    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || {
            client_loop(&addr, c as u64, batch_rows, pool, min_secs, retries)
        }));
    }
    let mut total = ClientStats { ok: 0, rejected: 0, reconnects: 0, mismatches: 0 };
    for h in handles {
        match h.join() {
            Ok(st) => {
                total.ok += st.ok;
                total.rejected += st.rejected;
                total.reconnects += st.reconnects;
                total.mismatches += st.mismatches;
            }
            Err(_) => {
                eprintln!("serve_load: client thread panicked");
                std::process::exit(1);
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "serve_load: {} ok ({:.0} req/s), {} rejected (retried), {} reconnects, {} mismatches \
         over {secs:.1}s with {clients} clients",
        total.ok,
        total.ok as f64 / secs,
        total.rejected,
        total.reconnects,
        total.mismatches
    );
    if total.mismatches > 0 {
        eprintln!("serve_load: FAILED — responses changed bitwise under load");
        std::process::exit(1);
    }
}

fn client_loop(
    addr: &str,
    id: u64,
    batch_rows: usize,
    pool: usize,
    min_secs: f64,
    retries: u32,
) -> ClientStats {
    // a generous budget: chaos mode is expected to tear sessions down,
    // and the whole point is that the retry policy absorbs it
    let policy = RetryPolicy { max_attempts: retries, seed: 0x5EED ^ id, ..RetryPolicy::default() };
    let mut sess = RetryingClient::connect(addr, policy).unwrap_or_else(|e| {
        eprintln!("serve_load client {id}: connect {addr}: {e}");
        std::process::exit(1);
    });
    let d = sess.input_dim();
    // a fixed, deterministic request pool per client: same batch in ⇒
    // same prediction out, forever (even across deterministic-retrain
    // hot swaps)
    let mut rng = Rng::new(1000 + id);
    let batches: Vec<Mat> =
        (0..pool).map(|_| Mat::from_vec(batch_rows, d, rng.gauss_vec(batch_rows * d))).collect();
    let mut first_seen: Vec<Option<Vec<f32>>> = vec![None; pool];
    let mut st = ClientStats { ok: 0, rejected: 0, reconnects: 0, mismatches: 0 };
    let t0 = Instant::now();
    let mut k = 0usize;
    while t0.elapsed().as_secs_f64() < min_secs {
        let idx = k % pool;
        k += 1;
        match sess.infer(&batches[idx]) {
            Ok(out) => {
                match &first_seen[idx] {
                    None => first_seen[idx] = Some(out.data.clone()),
                    Some(want) => {
                        if want != &out.data {
                            st.mismatches += 1;
                            eprintln!(
                                "serve_load client {id}: batch {idx} response changed bitwise"
                            );
                        }
                    }
                }
                st.ok += 1;
            }
            Err(e) => {
                // the retrying client already exhausted its budget —
                // under chaos that means the daemon is truly down, not
                // merely faulting
                eprintln!("serve_load client {id}: retry budget exhausted: {e}");
                std::process::exit(1);
            }
        }
    }
    st.rejected = sess.rejected();
    st.reconnects = sess.reconnects();
    st
}
