//! Table 2 (scaled): kernel ridge regression on the four UCI-like
//! datasets — RBF (exact + RFF) vs NTK (exact + NTKRF + NTKSketch) —
//! reporting 4-fold CV MSE and wallclock, streaming the feature methods
//! through the coordinator pipeline.
//!
//! Run: `cargo run --release --example uci_regression [--n 1200 --m 1024]`

use ntk_sketch::coordinator::{train_streaming, PipelineConfig};
use ntk_sketch::data::uci_like::{generate, ALL_FAMILIES};
use ntk_sketch::data::{split, Dataset};
use ntk_sketch::features::ntk_rf::{NtkRf, NtkRfConfig};
use ntk_sketch::features::ntk_sketch::{NtkSketch, NtkSketchConfig};
use ntk_sketch::features::rff::Rff;
use ntk_sketch::features::Featurizer;
use ntk_sketch::ntk::{ntk_cross_gram, ntk_gram};
use ntk_sketch::regression::{mse, KernelRidge};
use ntk_sketch::rng::Rng;
use ntk_sketch::tensor::Mat;
use ntk_sketch::util::cli::Args;
use ntk_sketch::util::timer::{fmt_secs, timed};

fn kernel_cv(ds: &Dataset, gram: impl Fn(&Mat) -> ntk_sketch::linalg::DMat, cross: impl Fn(&Mat, &Mat) -> ntk_sketch::linalg::DMat, lambda: f64, folds: usize) -> f64 {
    let parts = split::k_folds(ds.n(), folds, 31);
    let mut total = 0.0;
    for held in 0..folds {
        let tr_idx: Vec<usize> =
            (0..folds).filter(|&f| f != held).flat_map(|f| parts[f].iter().copied()).collect();
        let tr = split::subset(ds, &tr_idx);
        let te = split::subset(ds, &parts[held]);
        let k = gram(&tr.x);
        let kr = KernelRidge::fit(&k, &tr.y_mat(), lambda).unwrap();
        total += mse(&kr.predict(&cross(&te.x, &tr.x)), &te.y_mat());
    }
    total / folds as f64
}

fn feature_cv<F: Featurizer>(ds: &Dataset, f: &F, lambda: f64, folds: usize) -> f64 {
    let parts = split::k_folds(ds.n(), folds, 31);
    let mut total = 0.0;
    for held in 0..folds {
        let tr_idx: Vec<usize> =
            (0..folds).filter(|&ff| ff != held).flat_map(|ff| parts[ff].iter().copied()).collect();
        let tr = split::subset(ds, &tr_idx);
        let te = split::subset(ds, &parts[held]);
        // stream through the coordinator pipeline (the system path)
        let (mut reg, _stats) = train_streaming(
            &tr.x,
            &tr.y_mat(),
            f.dim(),
            || |xs: &Mat| f.transform(xs),
            PipelineConfig { shard_rows: 256, workers: 2, queue_depth: 4 },
        );
        reg.solve(lambda).unwrap();
        total += mse(&reg.predict(&f.transform(&te.x)), &te.y_mat());
    }
    total / folds as f64
}

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 1200);
    let m = args.usize("m", 1024);
    let depth = 1;
    let lambda = args.f64("lambda", 1e-3);
    let folds = 4;

    println!("Table 2 (scaled to n={n}, m={m}, 4-fold CV)\n");
    println!(
        "{:<18} {:>12} {:>10} | {:>12} {:>10}",
        "dataset", "method", "time", "MSE", ""
    );
    for fam in ALL_FAMILIES {
        let ds = generate(fam, n, 41);
        let mut rng = Rng::new(42);
        let sigma = Rff::median_sigma(&ds.x, &mut rng);

        // RBF exact
        let (mse_rbf, t_rbf) = timed(|| {
            kernel_cv(&ds, |x| Rff::gram(x, sigma), |a, b| {
                let mut g = ntk_sketch::linalg::DMat::zeros(a.rows, b.rows);
                for i in 0..a.rows {
                    for j in 0..b.rows {
                        let d2: f64 = a
                            .row(i)
                            .iter()
                            .zip(b.row(j).iter())
                            .map(|(&u, &v)| ((u - v) as f64).powi(2))
                            .sum();
                        *g.at_mut(i, j) = (-d2 / (2.0 * sigma * sigma)).exp();
                    }
                }
                g
            }, lambda, folds)
        });
        // RFF
        let rff = Rff::new(ds.d(), m, sigma, &mut rng);
        let (mse_rff, t_rff) = timed(|| feature_cv(&ds, &rff, lambda, folds));
        // exact NTK
        let (mse_ntk, t_ntk) = timed(|| {
            kernel_cv(&ds, |x| ntk_gram(depth, x), |a, b| ntk_cross_gram(depth, a, b), lambda, folds)
        });
        // NTKRF
        let ntkrf = NtkRf::new(ds.d(), NtkRfConfig::for_budget(depth, m), &mut rng);
        let (mse_ntkrf, t_ntkrf) = timed(|| feature_cv(&ds, &ntkrf, lambda, folds));
        // NTKSketch
        let sk = NtkSketch::new(ds.d(), NtkSketchConfig::for_budget(depth, m), &mut rng);
        let (mse_sk, t_sk) = timed(|| feature_cv(&ds, &sk, lambda, folds));

        let rows = [
            ("RBF (exact)", mse_rbf, t_rbf),
            ("RFF", mse_rff, t_rff),
            ("NTK (exact)", mse_ntk, t_ntk),
            ("NTKRF", mse_ntkrf, t_ntkrf),
            ("NTKSketch", mse_sk, t_sk),
        ];
        for (i, (name, e, t)) in rows.iter().enumerate() {
            let label = if i == 0 { fam.name() } else { "" };
            println!("{:<18} {:>12} {:>10} | {:>12.4} ", label, name, fmt_secs(*t), e);
        }
        println!();
    }
    println!("(paper-scale n: MillionSongs 467k, WorkLoads 180k, CT 53k, Protein 40k — the exact-kernel columns OOM there; see EXPERIMENTS.md)");
}
