//! Fig. 2a (scaled): classify the MNIST-like dataset with the three NTK
//! approximations — GradRF, NTKSketch and NTKRF — at a fixed feature
//! budget, with λ search on a validation split (the paper's §5.1
//! protocol).
//!
//! Run: `cargo run --release --example mnist_classification [--n 1500 --dim 1024]`

use ntk_sketch::data::{mnist_like, split};
use ntk_sketch::features::grad_rf::GradRfMlp;
use ntk_sketch::features::ntk_poly_sketch::NtkPolySketch;
use ntk_sketch::features::ntk_rf::{NtkRf, NtkRfConfig};
use ntk_sketch::features::ntk_sketch::{NtkSketch, NtkSketchConfig};
use ntk_sketch::features::Featurizer;
use ntk_sketch::regression::cv::{lambda_grid, select_lambda_classification};
use ntk_sketch::regression::{accuracy, RidgeRegressor};
use ntk_sketch::rng::Rng;
use ntk_sketch::util::cli::Args;
use ntk_sketch::util::timer::{fmt_secs, timed};

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 1500);
    let dim = args.usize("dim", 1024);
    let side = args.usize("side", 16);
    let depth = 1; // the paper uses depth L = 1 for MNIST (§5.1)
    let mut rng = Rng::new(args.u64("seed", 1));

    let ds = mnist_like::generate(n, side, 11).flatten();
    let (train0, test) = split::train_test(&ds, 0.2, 12);
    let (train, val) = split::train_test(&train0, 0.15, 13);
    println!(
        "mnist-like: train={} val={} test={} d={} classes={}  feature budget={dim}",
        train.n(),
        val.n(),
        test.n(),
        ds.d(),
        ds.classes
    );
    println!("{:<18} {:>9} {:>10} {:>12}", "method", "dim", "test acc", "featurize");

    let featurizers: Vec<(&str, Box<dyn Featurizer>)> = vec![
        ("GradRF", Box::new(GradRfMlp::for_feature_dim(ds.d(), depth.max(1), dim, &mut rng))),
        (
            "NTKSketch",
            Box::new(NtkSketch::new(ds.d(), NtkSketchConfig::for_budget(depth, dim), &mut rng)),
        ),
        (
            "NTKSketch(poly)",
            Box::new(NtkPolySketch::new(ds.d(), depth, 8, 2 * dim, dim, &mut rng)),
        ),
        (
            "NTKRF",
            Box::new(NtkRf::new(ds.d(), NtkRfConfig::for_budget(depth, dim), &mut rng)),
        ),
    ];

    for (name, f) in featurizers {
        let (out, t_feat) = timed(|| {
            let ftr = f.transform(&train.x);
            let fval = f.transform(&val.x);
            let fte = f.transform(&test.x);
            (ftr, fval, fte)
        });
        let (ftr, fval, fte) = out;
        let (lam, _) = select_lambda_classification(
            &ftr,
            &train.one_hot_centered(),
            &fval,
            &val.y,
            &lambda_grid(),
        );
        let r = RidgeRegressor::fit(&ftr, &train.one_hot_centered(), lam).unwrap();
        let acc = accuracy(&r.predict(&fte), &test.y);
        println!("{:<18} {:>9} {:>9.1}% {:>12}", name, f.dim(), 100.0 * acc, fmt_secs(t_feat));
    }
}
