//! Theorem 3 check: spectral approximation of the two-layer NTK matrix,
//! (1−ε)(K+λI) ⪯ ΨᵀΨ+λI ⪯ (1+ε)(K+λI), with leverage-score-modified
//! random features (Φ̃₁, Gibbs Algorithm 3) vs plain Φ₁ — the ablation
//! DESIGN.md calls out.
//!
//! ε is measured exactly: the extreme generalized eigenvalues of
//! (ΨᵀΨ+λI) vs (K+λI) via (K+λI)^{-1/2}(ΨᵀΨ+λI)(K+λI)^{-1/2}.
//!
//! Run: `cargo run --release --example spectral_approximation [--n 160]`

use ntk_sketch::features::ntk_rf::{NtkRf, NtkRfConfig, Phi1Mode};
use ntk_sketch::features::Featurizer;
use ntk_sketch::linalg::{jacobi_eigen, statistical_dimension, DMat};
use ntk_sketch::ntk::ntk_gram;
use ntk_sketch::rng::Rng;
use ntk_sketch::tensor::Mat;
use ntk_sketch::util::cli::Args;

/// Largest/smallest eigenvalues of (K+λI)^{-1/2} (F+λI) (K+λI)^{-1/2}.
fn spectral_band(k: &DMat, f: &DMat, lambda: f64) -> (f64, f64) {
    let n = k.rows;
    let mut kl = k.clone();
    kl.add_diag(lambda);
    let (evals, evecs) = jacobi_eigen(&kl, 100);
    // K^{-1/2} = V diag(1/sqrt(e)) V^T
    let mut inv_sqrt = DMat::zeros(n, n);
    for a in 0..n {
        for b in 0..n {
            let mut s = 0.0;
            for t in 0..n {
                s += evecs.at(a, t) * evecs.at(b, t) / evals[t].max(1e-12).sqrt();
            }
            *inv_sqrt.at_mut(a, b) = s;
        }
    }
    let mut fl = f.clone();
    fl.add_diag(lambda);
    let mid = inv_sqrt.matmul(&fl).matmul(&inv_sqrt);
    let (ev, _) = jacobi_eigen(&mid, 100);
    (ev[0], *ev.last().unwrap())
}

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 160);
    let d = args.usize("d", 24);
    let lambda = args.f64("lambda", 0.1);
    let m1 = args.usize("m1", 4096);
    let mut rng = Rng::new(args.u64("seed", 5));

    // unit-ball inputs (Theorem 3 precondition)
    let mut x = Mat::from_vec(n, d, rng.gauss_vec(n * d));
    x.normalize_rows();

    let k = ntk_gram(1, &x); // two-layer (L=1) NTK
    let (eigs, _) = jacobi_eigen(&k, 100);
    let s_lambda = statistical_dimension(&eigs, lambda);
    println!(
        "two-layer NTK on n={n} unit vectors, λ={lambda}: s_λ(K) = {s_lambda:.1}, ‖K‖ = {:.2}",
        eigs.last().unwrap()
    );
    println!("{:<22} {:>10} {:>10} {:>10}", "features", "min eig", "max eig", "ε band");

    for (name, mode) in [
        ("plain Φ1 (Eq. 11)", Phi1Mode::Plain),
        ("leverage Φ̃1 (Alg. 3)", Phi1Mode::Leverage { gibbs_sweeps: 1 }),
    ] {
        // average the band over a few feature draws; run each draw both
        // full-precision and with the bf16-storage mixing path so the
        // quantization's effect on ε is measured where it matters —
        // against the sampling error it has to hide under.
        let trials = 3;
        let (mut lo_acc, mut hi_acc) = (0.0, 0.0);
        let (mut lo_acc_q, mut hi_acc_q) = (0.0, 0.0);
        for t in 0..trials {
            let mut r2 = Rng::new(100 + t);
            let cfg = NtkRfConfig { depth: 1, m0: 2048, m1, ms: 1024, phi1_mode: mode };
            let mut rf = NtkRf::new(d, cfg, &mut r2);
            let feats = rf.transform(&x);
            // data-side Gram ΨᵀΨ (n×n in the paper's column convention)
            let f = DMat::from_mat(&feats.gram());
            let (lo, hi) = spectral_band(&k, &f, lambda);
            lo_acc += lo;
            hi_acc += hi;
            rf.enable_bf16_mix();
            let fq = DMat::from_mat(&rf.transform(&x).gram());
            let (lo_q, hi_q) = spectral_band(&k, &fq, lambda);
            lo_acc_q += lo_q;
            hi_acc_q += hi_q;
        }
        let (lo, hi) = (lo_acc / trials as f64, hi_acc / trials as f64);
        let eps = (1.0 - lo).max(hi - 1.0);
        println!("{:<22} {:>10.3} {:>10.3} {:>10.3}", name, lo, hi, eps);
        let (lo_q, hi_q) = (lo_acc_q / trials as f64, hi_acc_q / trials as f64);
        let eps_q = (1.0 - lo_q).max(hi_q - 1.0);
        println!(
            "{:<22} {:>10.3} {:>10.3} {:>10.3}   (Δε = {:+.4} vs f32)",
            "  └ bf16 mix", lo_q, hi_q, eps_q, eps_q - eps
        );
    }
    println!("\nTheorem 3: with m₀ = O(n/(ε²λ)), m₁ = O(d·min(rank², ‖X‖²/λ)/ε²) the band is (1±ε).");
    println!(
        "bf16-storage mixing (DESIGN.md §7) perturbs each mix by ≤ 2⁻⁷ relative — \
         Δε above shows it vanishes under the m-driven sampling error."
    );
}
