//! Quickstart: 60-second tour of the library.
//!
//! 1. Evaluate the exact ReLU-NTK (Definition 1 / Eq. 5).
//! 2. Approximate it with NTKRF (Alg. 2) and NTKSketch (Alg. 1) features.
//! 3. Train a ridge classifier on the features and compare against exact
//!    kernel ridge regression.
//!
//! Run: `cargo run --release --example quickstart`

use ntk_sketch::data::{split, synth};
use ntk_sketch::features::ntk_rf::{NtkRf, NtkRfConfig};
use ntk_sketch::features::ntk_sketch::{NtkSketch, NtkSketchConfig};
use ntk_sketch::features::Featurizer;
use ntk_sketch::ntk::{k_relu, ntk_cross_gram, ntk_gram, theta_ntk};
use ntk_sketch::regression::{accuracy, KernelRidge, RidgeRegressor};
use ntk_sketch::rng::Rng;
use ntk_sketch::tensor::dot;
use ntk_sketch::util::timer::timed;

fn main() {
    let mut rng = Rng::new(42);

    println!("== 1. the ReLU-NTK function (Fig. 1) ==");
    for depth in [2usize, 4, 8] {
        println!(
            "  K_relu^({depth})(-1) = {:.3}   K_relu^({depth})(0) = {:.3}   K_relu^({depth})(1) = {:.3}",
            k_relu(depth, -1.0),
            k_relu(depth, 0.0),
            k_relu(depth, 1.0)
        );
    }

    println!("\n== 2. feature maps approximate the kernel ==");
    let d = 16;
    let depth = 2;
    let y = rng.gauss_vec(d);
    let z = rng.gauss_vec(d);
    let exact = theta_ntk(depth, &y, &z);
    let rf = NtkRf::new(d, NtkRfConfig { depth, m0: 512, m1: 2048, ms: 512, phi1_mode: ntk_sketch::features::ntk_rf::Phi1Mode::Plain }, &mut rng);
    let approx_rf = dot(&rf.features(&y), &rf.features(&z)) as f64;
    let sk = NtkSketch::new(d, NtkSketchConfig::for_budget(depth, 1024), &mut rng);
    let approx_sk = dot(&sk.features(&y), &sk.features(&z)) as f64;
    println!("  Θ_ntk(y,z) exact    = {exact:.4}");
    println!("  <Ψ_rf(y), Ψ_rf(z)>  = {approx_rf:.4}  (NTKRF, Alg. 2)");
    println!("  <Ψ_sk(y), Ψ_sk(z)>  = {approx_sk:.4}  (NTKSketch, Alg. 1)");

    println!("\n== 3. learning: features + linear ridge vs exact kernel ridge ==");
    let ds = synth::gaussian_mixture(600, d, 4, 0.9, 7);
    let (train, test) = split::train_test(&ds, 0.25, 8);

    // exact NTK kernel ridge (the O(n²) baseline)
    let (acc_exact, t_exact) = timed(|| {
        let k = ntk_gram(depth, &train.x);
        let kr = KernelRidge::fit(&k, &train.one_hot_centered(), 1e-4).unwrap();
        let pred = kr.predict(&ntk_cross_gram(depth, &test.x, &train.x));
        accuracy(&pred, &test.y)
    });

    // NTKRF features + streaming ridge (the paper's fast path)
    let (acc_rf, t_rf) = timed(|| {
        let ftr = rf.transform(&train.x);
        let fte = rf.transform(&test.x);
        let r = RidgeRegressor::fit(&ftr, &train.one_hot_centered(), 1e-4).unwrap();
        accuracy(&r.predict(&fte), &test.y)
    });

    println!("  exact NTK ridge : acc {:.3}  ({:.2}s)", acc_exact, t_exact);
    println!("  NTKRF + ridge   : acc {:.3}  ({:.2}s)", acc_rf, t_rf);
    println!("\nDone. See examples/ for the paper's experiments and `cargo bench` for the tables/figures.");
}
