"""L2 model: shapes, homogeneity, and statistical agreement with the
exact NTK (Theorem 2)."""

import numpy as np

from compile.model import NtkRfConfig, build_fn, init_params, param_layout
from compile.kernels import ref


def test_shapes_and_layout():
    cfg = NtkRfConfig(depth=2, d=16, m0=32, m1=64, ms=32, batch=4)
    params = init_params(cfg, seed=0)
    layout = param_layout(cfg)
    assert len(params) == len(layout)
    assert len(layout) >= 12  # 6 per layer + shared hadamard blocks
    for p, (_, shape) in zip(params, layout):
        assert p.shape == tuple(shape)
    fn = build_fn(cfg)
    x = np.random.RandomState(0).randn(4, 16).astype(np.float32)
    (feats,) = fn(x, *params)
    assert feats.shape == (4, cfg.feature_dim)


def test_scale_homogeneity_and_zero():
    cfg = NtkRfConfig(depth=2, d=8, m0=16, m1=32, ms=16, batch=3)
    params = init_params(cfg, seed=1)
    fn = build_fn(cfg)
    rng = np.random.RandomState(2)
    x = rng.randn(3, 8).astype(np.float32)
    x[2] = 0.0
    (f1,) = fn(x, *params)
    (f2,) = fn(2.0 * x, *params)
    f1, f2 = np.asarray(f1), np.asarray(f2)
    np.testing.assert_allclose(f2[:2], 2.0 * f1[:2], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(f1[2], 0.0, atol=1e-6)


def test_inner_products_approximate_ntk():
    # Theorem 2: <Ψ(y),Ψ(z)> ≈ Θ^{(L)}(y,z); average over fresh parameter
    # draws and compare with the exact Definition-1 value.
    depth, d = 2, 10
    cfg = NtkRfConfig(depth=depth, d=d, m0=512, m1=2048, ms=512, batch=2)
    rng = np.random.RandomState(3)
    y = rng.randn(d).astype(np.float32)
    z = rng.randn(d).astype(np.float32)
    x = np.stack([y, z])
    exact = ref.theta_ntk_ref(y, z, depth)
    fn = build_fn(cfg)
    trials = 6
    acc = 0.0
    for t in range(trials):
        params = init_params(cfg, seed=100 + t)
        (f,) = fn(x, *params)
        f = np.asarray(f)
        acc += float(f[0] @ f[1])
    mean = acc / trials
    assert abs(mean - exact) < 0.12 * (abs(exact) + 1.0), f"mean={mean} exact={exact}"


def test_self_kernel_tracks_depth_plus_one():
    depth, d = 3, 8
    cfg = NtkRfConfig(depth=depth, d=d, m0=256, m1=1024, ms=256, batch=1)
    rng = np.random.RandomState(4)
    x = rng.randn(1, d).astype(np.float32)
    n2 = float((x**2).sum())
    fn = build_fn(cfg)
    acc = 0.0
    trials = 6
    for t in range(trials):
        params = init_params(cfg, seed=200 + t)
        (f,) = fn(x, *params)
        acc += float((np.asarray(f) ** 2).sum())
    mean = acc / trials
    exact = (depth + 1) * n2
    assert abs(mean - exact) < 0.15 * exact, f"mean={mean} exact={exact}"
