"""Hypothesis sweeps of the Pallas matmul+activation kernel vs ref."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul
from compile.kernels import ref


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 48),
    k=st.integers(1, 40),
    n=st.integers(1, 48),
    act=st.sampled_from([matmul.ACT_NONE, matmul.ACT_RELU, matmul.ACT_STEP]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_act_matches_ref(b, k, n, act, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(b, k).astype(np.float32)
    wt = rng.randn(k, n).astype(np.float32)
    got = np.asarray(matmul.matmul_act(x, wt, act=act, scale=0.5))
    want = np.asarray(ref.matmul_act_ref(x, wt, act=act, scale=0.5))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32])
def test_dtypes(dtype):
    # (jax keeps the default x64-disabled config: float64 inputs are
    # traced as f32, so f32 + bf16 below are the supported dtypes)
    rng = np.random.RandomState(0)
    x = rng.randn(8, 16).astype(dtype)
    wt = rng.randn(16, 8).astype(dtype)
    got = np.asarray(matmul.matmul_act(x, wt, act=matmul.ACT_RELU))
    want = np.asarray(ref.matmul_act_ref(x, wt, act=matmul.ACT_RELU))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
    assert got.dtype == dtype


def test_bf16_runs():
    import jax.numpy as jnp

    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(16, 32), dtype=jnp.bfloat16)
    wt = jnp.asarray(rng.randn(32, 16), dtype=jnp.bfloat16)
    got = np.asarray(matmul.matmul_act(x, wt, act=matmul.ACT_RELU), dtype=np.float32)
    want = np.asarray(
        ref.matmul_act_ref(x.astype(jnp.float32), wt.astype(jnp.float32), act=matmul.ACT_RELU)
    )
    # bf16 has ~3 decimal digits
    np.testing.assert_allclose(got, want, rtol=0.1, atol=0.1)


def test_pick_block_divides():
    for n in [1, 7, 64, 100, 128, 129, 384, 1000]:
        b = matmul.pick_block(n)
        assert n % b == 0 and b <= 128


def test_vmem_estimate_reasonable():
    # 128-tile matmul over k=512: x tile 256 KiB + w tile 256 KiB + out 64 KiB
    est = matmul.vmem_bytes_estimate(128, 512, 128)
    assert est == 4 * (128 * 512 + 512 * 128 + 128 * 128)
    assert est < 2 * 1024 * 1024  # DESIGN §Perf budget
