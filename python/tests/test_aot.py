"""AOT round trip: the lowered HLO text parses, the golden pair is
self-consistent, and the manifest layout matches the weights blob."""

import json
import os

import numpy as np

from compile.aot import build_artifacts, to_hlo_text
from compile.model import NtkRfConfig, build_fn, init_params

import jax


def small_cfg():
    return NtkRfConfig(depth=2, d=16, m0=32, m1=64, ms=32, batch=8)


def test_hlo_text_nonempty_and_entry(tmp_path):
    cfg = small_cfg()
    params = init_params(cfg, seed=0)
    fn = build_fn(cfg)
    specs = [jax.ShapeDtypeStruct(p.shape, np.float32) for p in params]
    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((cfg.batch, cfg.d), np.float32), *specs)
    hlo = to_hlo_text(lowered)
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # one parameter per input (x + params)
    assert hlo.count("parameter(") >= 1 + len(params)


def test_artifact_bundle_consistency(tmp_path):
    cfg = small_cfg()
    out = str(tmp_path)
    build_artifacts(cfg, seed=3, out_dir=out, name="t")
    man = json.load(open(os.path.join(out, "t.manifest.json")))
    assert man["feature_dim"] == cfg.feature_dim
    total = sum(int(np.prod(p["shape"])) for p in man["params"])
    blob = open(os.path.join(out, "t.weights.bin"), "rb").read()
    assert len(blob) == 4 * total
    gin = np.frombuffer(open(os.path.join(out, "t.golden_in.bin"), "rb").read(), dtype="<f4")
    gout = np.frombuffer(open(os.path.join(out, "t.golden_out.bin"), "rb").read(), dtype="<f4")
    assert gin.size == cfg.batch * cfg.d
    assert gout.size == cfg.batch * cfg.feature_dim

    # replay: weights blob + golden input must reproduce golden output
    params = init_params(cfg, seed=3)
    off = 0
    arr = np.frombuffer(blob, dtype="<f4")
    for p in params:
        n = p.size
        np.testing.assert_array_equal(arr[off : off + n], p.ravel())
        off += n
    fn = build_fn(cfg)
    (y,) = fn(gin.reshape(cfg.batch, cfg.d), *params)
    np.testing.assert_allclose(
        np.asarray(y).ravel(), gout, rtol=1e-4, atol=1e-5
    )


def test_deterministic_weights():
    cfg = small_cfg()
    a = init_params(cfg, seed=9)
    b = init_params(cfg, seed=9)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
