"""Blocked-FWHT kernel vs dense Hadamard oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fwht
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 16),
    logn=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwht_matches_dense(b, logn, seed):
    n = 1 << logn
    rng = np.random.RandomState(seed)
    x = rng.randn(b, n).astype(np.float32)
    got = np.asarray(fwht.fwht_norm(x))
    want = np.asarray(ref.fwht_norm_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_fwht_is_isometry():
    rng = np.random.RandomState(3)
    x = rng.randn(4, 512).astype(np.float32)
    y = np.asarray(fwht.fwht_norm(x))
    np.testing.assert_allclose(
        np.linalg.norm(x, axis=1), np.linalg.norm(y, axis=1), rtol=1e-4
    )


def test_fwht_involution():
    rng = np.random.RandomState(4)
    x = rng.randn(2, 256).astype(np.float32)
    y = np.asarray(fwht.fwht_norm(np.asarray(fwht.fwht_norm(x))))
    np.testing.assert_allclose(y, x, rtol=1e-3, atol=1e-4)


def test_multi_stage_factorization():
    # n = 2^14 exercises the two-stage (H_a ⊗ I)(I ⊗ H_c) path
    fs = fwht._factor(1 << 14)
    assert all(f <= 128 for f in fs)
    assert np.prod(fs) == 1 << 14
    rng = np.random.RandomState(5)
    x = rng.randn(1, 1 << 14).astype(np.float32)
    y = np.asarray(fwht.fwht_norm(x))
    # isometry is a sufficient smoke check at this size
    np.testing.assert_allclose(np.linalg.norm(y), np.linalg.norm(x), rtol=1e-4)


def test_hadamard_matrix_orthogonal():
    h = fwht.hadamard_matrix(64)
    np.testing.assert_allclose(h @ h.T, 64 * np.eye(64), atol=1e-5)
