"""Arc-cosine feature kernels and TensorSRHT vs oracles + statistics."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import arccos, ref, tensor_srht


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 12), d=st.integers(1, 24), m=st.integers(1, 48), seed=st.integers(0, 2**31 - 1))
def test_phi_kernels_match_ref(b, d, m, seed):
    rng = np.random.RandomState(seed)
    x = rng.randn(b, d).astype(np.float32)
    wt = rng.randn(d, m).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(arccos.phi0(x, wt)), np.asarray(ref.phi0_ref(x, wt)), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(arccos.phi1(x, wt)), np.asarray(ref.phi1_ref(x, wt)), rtol=1e-4, atol=1e-5
    )


def test_phi_expectations_estimate_arc_cosine_kernels():
    # E<Φ0(y),Φ0(z)> = κ0(cos), E<Φ1(y),Φ1(z)> = κ1(cos) for unit y, z
    rng = np.random.RandomState(7)
    d, m = 10, 200_000
    y = rng.randn(d).astype(np.float32)
    z = rng.randn(d).astype(np.float32)
    y /= np.linalg.norm(y)
    z /= np.linalg.norm(z)
    wt = rng.randn(d, m).astype(np.float32)
    x = np.stack([y, z])
    f0 = np.asarray(arccos.phi0(x, wt))
    f1 = np.asarray(arccos.phi1(x, wt))
    cos = float(y @ z)
    assert abs(f0[0] @ f0[1] - ref.kappa0(cos)) < 0.01
    assert abs(f1[0] @ f1[1] - ref.kappa1(cos)) < 0.01


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 8),
    da=st.integers(1, 20),
    db=st.integers(1, 20),
    m=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_tensor_srht_matches_ref(b, da, db, m, seed):
    rng = np.random.RandomState(seed)
    a = rng.randn(b, da).astype(np.float32)
    bb = rng.randn(b, db).astype(np.float32)
    d1, d2, sel1t, sel2t = tensor_srht.make_params(rng, da, db, m)
    got = np.asarray(tensor_srht.tensor_srht(a, bb, d1, d2, sel1t, sel2t))
    want = np.asarray(ref.tensor_srht_ref(a, bb, d1, d2, sel1t, sel2t))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_tensor_srht_unbiased_inner_product():
    # E<Q²(a⊗b), Q²(a'⊗b')> = <a,a'>·<b,b'>
    rng = np.random.RandomState(11)
    da, db, m = 12, 9, 64
    a, a2 = rng.randn(da).astype(np.float32), rng.randn(da).astype(np.float32)
    b, b2 = rng.randn(db).astype(np.float32), rng.randn(db).astype(np.float32)
    exact = float((a @ a2) * (b @ b2))
    trials = 400
    acc = 0.0
    for _ in range(trials):
        d1, d2, sel1t, sel2t = tensor_srht.make_params(rng, da, db, m)
        qa = np.asarray(
            tensor_srht.tensor_srht(np.stack([a, a2]), np.stack([b, b2]), d1, d2, sel1t, sel2t)
        )
        acc += float(qa[0] @ qa[1])
    mean = acc / trials
    assert abs(mean - exact) < 0.2 * (abs(exact) + 1.0), f"mean={mean} exact={exact}"
