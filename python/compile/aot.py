"""AOT lowering: JAX model -> HLO *text* + weights + manifest + goldens.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

Artifacts written to --out-dir (default ../artifacts):
  <name>.hlo.txt        the lowered computation  f(x, *params) -> (features,)
  <name>.weights.bin    all parameter arrays, f32 little-endian, in order
  <name>.manifest.json  shapes/order of inputs + golden file names
  <name>.golden_in.bin  one example batch (f32)
  <name>.golden_out.bin its features under the jitted fn (f32)

Usage: python -m compile.aot [--depth 2 --d 64 --m0 128 --m1 512 --ms 128
                              --batch 64 --seed 0 --out-dir ../artifacts]
"""

import argparse
import json
import os

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from .model import NtkRfConfig, build_fn, init_params, param_layout


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    hlo = comp.as_hlo_text()
    # as_hlo_text elides large constants as `constant({...})`; the
    # xla_extension-0.5.1 parser silently reads those as ZEROS. All big
    # tensors must be parameters (model.hadamard_sizes etc.). Fail loudly
    # if any slipped through.
    if "{...}" in hlo:
        raise RuntimeError(
            "lowered HLO contains an elided constant ('constant({...})') — "
            "it would silently become zeros on the Rust side; pass the "
            "tensor as a parameter instead"
        )
    return hlo


def build_artifacts(cfg: NtkRfConfig, seed: int, out_dir: str, name: str = "ntk_rf"):
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg, seed=seed)
    fn = build_fn(cfg)
    x_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.d), np.float32)
    p_specs = [jax.ShapeDtypeStruct(p.shape, np.float32) for p in params]
    lowered = jax.jit(fn).lower(x_spec, *p_specs)
    hlo = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(hlo)

    # weights blob
    weights_path = os.path.join(out_dir, f"{name}.weights.bin")
    with open(weights_path, "wb") as f:
        for p in params:
            f.write(np.ascontiguousarray(p, dtype="<f4").tobytes())

    # golden pair
    rng = np.random.RandomState(seed + 1)
    x = rng.randn(cfg.batch, cfg.d).astype(np.float32)
    y = np.asarray(jax.jit(fn)(x, *params)[0], dtype=np.float32)
    with open(os.path.join(out_dir, f"{name}.golden_in.bin"), "wb") as f:
        f.write(np.ascontiguousarray(x, dtype="<f4").tobytes())
    with open(os.path.join(out_dir, f"{name}.golden_out.bin"), "wb") as f:
        f.write(np.ascontiguousarray(y, dtype="<f4").tobytes())

    manifest = {
        "name": name,
        "model": "ntk_rf",
        "depth": cfg.depth,
        "d": cfg.d,
        "m0": cfg.m0,
        "m1": cfg.m1,
        "ms": cfg.ms,
        "batch": cfg.batch,
        "feature_dim": cfg.feature_dim,
        "seed": seed,
        "hlo": f"{name}.hlo.txt",
        "weights": f"{name}.weights.bin",
        "golden_in": f"{name}.golden_in.bin",
        "golden_out": f"{name}.golden_out.bin",
        "params": [
            {"name": pname, "shape": list(shape)} for pname, shape in param_layout(cfg)
        ],
    }
    man_path = os.path.join(out_dir, f"{name}.manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    return hlo_path, weights_path, man_path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m0", type=int, default=128)
    ap.add_argument("--m1", type=int, default=512)
    ap.add_argument("--ms", type=int, default=128)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--name", type=str, default="ntk_rf")
    ap.add_argument("--out-dir", type=str, default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    cfg = NtkRfConfig(
        depth=args.depth, d=args.d, m0=args.m0, m1=args.m1, ms=args.ms, batch=args.batch
    )
    paths = build_artifacts(cfg, args.seed, args.out_dir, name=args.name)
    for p in paths:
        print(f"wrote {p}")


if __name__ == "__main__":
    main()
