"""L2: the NTKRF feature map (paper Algorithm 2) as a JAX program calling
the L1 Pallas kernels.

    φ⁰ = ψ⁰ = x/‖x‖
    per layer ℓ: φ̇^ℓ = Φ₀(φ^{ℓ−1}); φ^ℓ = Φ₁(φ^{ℓ−1});
                 ψ^ℓ = φ^ℓ ⊕ Q²(φ̇^ℓ ⊗ ψ^{ℓ−1})
    Ψ(x) = ‖x‖·ψ^L   ∈ ℝ^{m₁+m_s}

Parameters are generated in numpy (`init_params`) with a deterministic
seed, serialized by aot.py, and fed back in as HLO inputs by the Rust
runtime — Python never runs on the request path.
"""

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from .kernels import arccos, fwht, tensor_srht


@dataclass(frozen=True)
class NtkRfConfig:
    depth: int = 2
    d: int = 64
    m0: int = 128
    m1: int = 512
    ms: int = 128
    batch: int = 64

    @property
    def feature_dim(self) -> int:
        return self.m1 + self.ms


def hadamard_sizes(cfg: NtkRfConfig):
    """Hadamard block sizes the model's FWHT stages contract against.
    These ride along as parameters: `as_hlo_text()` elides large baked-in
    constants and the old XLA text parser reads the elision as zeros."""
    sizes = set()
    psi_dim = cfg.d
    for _ in range(cfg.depth):
        sizes |= fwht.needed_block_sizes(tensor_srht.next_pow2(cfg.m0))
        sizes |= fwht.needed_block_sizes(tensor_srht.next_pow2(psi_dim))
        psi_dim = cfg.m1 + cfg.ms
    return sorted(sizes)


def init_params(cfg: NtkRfConfig, seed: int = 0):
    """Flat, ordered list of numpy parameter arrays (one entry per HLO
    input after x). Order per layer:
      w0t [prev, m0], w1t [prev, m1], d1 [Pa], d2 [Pb],
      sel1t [Pa, ms], sel2t [Pb, ms]
    followed by the shared Hadamard blocks (ascending size).
    """
    rng = np.random.RandomState(seed)
    params = []
    phi_dim = cfg.d
    psi_dim = cfg.d
    for _ in range(cfg.depth):
        params.append(rng.randn(phi_dim, cfg.m0).astype(np.float32))  # w0t
        params.append(rng.randn(phi_dim, cfg.m1).astype(np.float32))  # w1t
        d1, d2, sel1t, sel2t = tensor_srht.make_params(rng, cfg.m0, psi_dim, cfg.ms)
        params.extend([d1, d2, sel1t, sel2t])
        phi_dim = cfg.m1
        psi_dim = cfg.m1 + cfg.ms
    for size in hadamard_sizes(cfg):
        params.append(fwht.hadamard_matrix(size))
    return params


def param_layout(cfg: NtkRfConfig):
    """Shapes (in order) of init_params output — for the manifest."""
    shapes = []
    phi_dim = cfg.d
    psi_dim = cfg.d
    for _ in range(cfg.depth):
        pa = tensor_srht.next_pow2(cfg.m0)
        pb = tensor_srht.next_pow2(psi_dim)
        shapes.append(("w0t", (phi_dim, cfg.m0)))
        shapes.append(("w1t", (phi_dim, cfg.m1)))
        shapes.append(("d1", (pa,)))
        shapes.append(("d2", (pb,)))
        shapes.append(("sel1t", (pa, cfg.ms)))
        shapes.append(("sel2t", (pb, cfg.ms)))
        phi_dim = cfg.m1
        psi_dim = cfg.m1 + cfg.ms
    for size in hadamard_sizes(cfg):
        shapes.append((f"hadamard_{size}", (size, size)))
    return shapes


def ntk_rf_features(cfg: NtkRfConfig, x, *params, interpret: bool = True):
    """Batched Algorithm 2: x [B, d] -> features [B, m1+ms]."""
    assert x.shape[1] == cfg.d
    sizes = hadamard_sizes(cfg)
    hblocks = {
        size: params[len(params) - len(sizes) + i] for i, size in enumerate(sizes)
    }
    norms = jnp.sqrt(jnp.sum(x * x, axis=1, keepdims=True))
    safe = jnp.maximum(norms, 1e-12)
    phi = x / safe
    psi = phi
    idx = 0
    for _ in range(cfg.depth):
        w0t, w1t, d1, d2, sel1t, sel2t = params[idx : idx + 6]
        idx += 6
        phi_dot = arccos.phi0(phi, w0t, interpret=interpret)
        phi_new = arccos.phi1(phi, w1t, interpret=interpret)
        q2 = tensor_srht.tensor_srht(
            phi_dot, psi, d1, d2, sel1t, sel2t, hblocks, interpret=interpret
        )
        psi = jnp.concatenate([phi_new, q2], axis=1)
        phi = phi_new
    # zero inputs map to zero features (norm factor restores scale)
    return psi * norms


def build_fn(cfg: NtkRfConfig, interpret: bool = True):
    """Return f(x, *params) suitable for jax.jit / AOT lowering."""

    def fn(x, *params):
        return (ntk_rf_features(cfg, x, *params, interpret=interpret),)

    return fn
