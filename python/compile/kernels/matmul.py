"""L1 Pallas kernel: tiled matmul with optional fused activation.

The single compute primitive every hot spot in this repo reduces to on
TPU-shaped hardware (see DESIGN.md §Hardware-Adaptation):

- arc-cosine random features  act(x @ W^T) * scale   (act = relu / step)
- blocked FWHT stages         x_blocked @ H_b        (H_b in VMEM)
- TensorSRHT gather           spectrum @ Sel^T       (one-hot selection)

BlockSpec tiles rows of `x` and columns of `w` into VMEM; the contraction
dimension is kept whole per tile (our models keep d ≤ 4096, i.e. ≤ 2 MiB
per f32 tile at bm = 128). MUST run interpret=True on CPU — real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# activation codes
ACT_NONE = 0
ACT_RELU = 1
ACT_STEP = 2


def _matmul_kernel(x_ref, wt_ref, o_ref, *, act: int, scale: float):
    """One (bm × bn) output tile: o = act(x @ wt) * scale."""
    acc = jnp.dot(x_ref[...], wt_ref[...], preferred_element_type=jnp.float32)
    if act == ACT_RELU:
        acc = jnp.maximum(acc, 0.0)
    elif act == ACT_STEP:
        acc = jnp.where(acc > 0.0, 1.0, 0.0)
    o_ref[...] = (acc * scale).astype(o_ref.dtype)


def pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is ≤ target (VMEM/MXU tile size)."""
    b = min(n, target)
    while n % b != 0:
        b -= 1
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("act", "scale", "interpret"))
def matmul_act(x, wt, *, act: int = ACT_NONE, scale: float = 1.0, interpret: bool = True):
    """act(x @ wt) * scale with x: [B, k], wt: [k, n] -> [B, n].

    Grid over (B/bm, n/bn) output tiles; the k dimension rides whole in
    each tile (k ≤ a few thousand in all our models).
    """
    b, k = x.shape
    k2, n = wt.shape
    assert k == k2, f"matmul_act: contraction mismatch {k} vs {k2}"
    bm = pick_block(b)
    bn = pick_block(n)
    kernel = functools.partial(_matmul_kernel, act=act, scale=scale)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        grid=(b // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=interpret,
    )(x, wt)


def vmem_bytes_estimate(b: int, k: int, n: int, dtype_bytes: int = 4) -> int:
    """Structural VMEM footprint per grid step (perf model, DESIGN §Perf)."""
    bm = pick_block(b)
    bn = pick_block(n)
    return dtype_bytes * (bm * k + k * bn + bm * bn)
