"""L1 Pallas kernels (interpret=True on CPU) + pure-jnp oracles."""
