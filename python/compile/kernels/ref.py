"""Pure-jnp/numpy oracles for every L1 kernel — the correctness ground
truth swept by hypothesis in python/tests/."""

import math

import numpy as np
import jax.numpy as jnp


def matmul_act_ref(x, wt, act: int = 0, scale: float = 1.0):
    out = jnp.dot(x, wt)
    if act == 1:
        out = jnp.maximum(out, 0.0)
    elif act == 2:
        out = jnp.where(out > 0.0, 1.0, 0.0)
    return out * scale


def hadamard_ref(n: int) -> np.ndarray:
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def fwht_norm_ref(x):
    """Dense-matrix orthonormal Hadamard transform over the last axis."""
    n = x.shape[-1]
    h = jnp.asarray(hadamard_ref(n)) / math.sqrt(n)
    return jnp.dot(x, h)  # H symmetric


def phi0_ref(x, wt):
    m = wt.shape[1]
    return matmul_act_ref(x, wt, act=2, scale=math.sqrt(2.0 / m))


def phi1_ref(x, wt):
    m = wt.shape[1]
    return matmul_act_ref(x, wt, act=1, scale=math.sqrt(2.0 / m))


def tensor_srht_ref(a, b, d1, d2, sel1t, sel2t):
    """Oracle TensorSRHT: dense Hadamard + explicit gather."""
    pa, m = sel1t.shape
    pb, _ = sel2t.shape
    ap = jnp.pad(a, ((0, 0), (0, pa - a.shape[1]))) * d1[None, :]
    bp = jnp.pad(b, ((0, 0), (0, pb - b.shape[1]))) * d2[None, :]
    sa = fwht_norm_ref(ap)
    sb = fwht_norm_ref(bp)
    i1 = np.argmax(np.asarray(sel1t), axis=0)
    i2 = np.argmax(np.asarray(sel2t), axis=0)
    scale = math.sqrt(pa * pb / m)
    return sa[:, i1] * sb[:, i2] * scale


def kappa0(alpha):
    a = np.clip(alpha, -1.0, 1.0)
    return (np.pi - np.arccos(a)) / np.pi


def kappa1(alpha):
    a = np.clip(alpha, -1.0, 1.0)
    return (np.sqrt(np.maximum(0.0, 1.0 - a * a)) + a * (np.pi - np.arccos(a))) / np.pi


def theta_ntk_ref(y, z, depth: int):
    """Exact fully-connected ReLU NTK (Definition 1 + Eq. 5), numpy."""
    ny = float(np.linalg.norm(y))
    nz = float(np.linalg.norm(z))
    if ny == 0.0 or nz == 0.0:
        return 0.0
    cos = float(np.clip(np.dot(y, z) / (ny * nz), -1.0, 1.0))
    sig = cos
    k = cos
    for _ in range(depth):
        sd = float(kappa0(sig))
        sig = float(kappa1(sig))
        k = k * sd + sig
    return ny * nz * k
