"""L1: fused arc-cosine random-feature blocks (paper Eq. 11).

Φ₀(x) = √(2/m)·Step(x Wᵀ),  Φ₁(x) = √(2/m)·ReLU(x Wᵀ)

One fused Pallas matmul+activation tile per output block — the dominant
FLOPs of NTKRF (Algorithm 2). `w` is passed already transposed ([d, m])
so the kernel's RHS layout is contraction-major.
"""

import math

from . import matmul


def phi0(x, wt, *, interpret: bool = True):
    """Step features: x [B, d], wt [d, m] -> [B, m] scaled by √(2/m)."""
    m = wt.shape[1]
    return matmul.matmul_act(
        x, wt, act=matmul.ACT_STEP, scale=math.sqrt(2.0 / m), interpret=interpret
    )


def phi1(x, wt, *, interpret: bool = True):
    """ReLU features: x [B, d], wt [d, m] -> [B, m] scaled by √(2/m)."""
    m = wt.shape[1]
    return matmul.matmul_act(
        x, wt, act=matmul.ACT_RELU, scale=math.sqrt(2.0 / m), interpret=interpret
    )
