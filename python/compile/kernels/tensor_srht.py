"""L1: degree-2 TensorSRHT Q²(a ⊗ b) (paper §1.3, Algorithm 2 line 6).

Q²(a⊗b)[k] = √(Pa·Pb/m) · (H D₁ a)[i_k] · (H D₂ b)[j_k]

TPU adaptation: the coordinate gather (a warp-level scatter on GPU) is
expressed as two one-hot *selection matmuls* — Sel₁ [m, Pa], Sel₂ [m, Pb]
with a single 1 per row — so the whole transform is FWHT-stage matmuls,
two selection matmuls and one fused elementwise product: all MXU work.
"""

import math

import jax.numpy as jnp

from . import fwht, matmul


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def tensor_srht(a, b, d1, d2, sel1t, sel2t, hblocks=None, *, interpret: bool = True):
    """Q²(a ⊗ b) for batches.

    a: [B, da], b: [B, db]
    d1: [Pa] signs (Pa = next_pow2(da)), d2: [Pb] signs
    sel1t: [Pa, m] one-hot columns, sel2t: [Pb, m]
    hblocks: size -> Hadamard block (traced params for AOT; see fwht.py)
    returns [B, m]
    """
    bsz, da = a.shape
    _, db = b.shape
    pa, m = sel1t.shape
    pb, m2 = sel2t.shape
    assert m == m2
    assert pa == next_pow2(da) and pb == next_pow2(db), "selection dims must match padding"
    ap = jnp.pad(a, ((0, 0), (0, pa - da))) * d1[None, :]
    bp = jnp.pad(b, ((0, 0), (0, pb - db))) * d2[None, :]
    sa = fwht.fwht_norm(ap, hblocks, interpret=interpret)
    sb = fwht.fwht_norm(bp, hblocks, interpret=interpret)
    ga = matmul.matmul_act(sa, sel1t, interpret=interpret)
    gb = matmul.matmul_act(sb, sel2t, interpret=interpret)
    scale = math.sqrt(pa * pb / m)
    return ga * gb * scale


def make_params(rng, da: int, db: int, m: int):
    """Numpy parameter pack for one TensorSRHT instance."""
    import numpy as np

    pa, pb = next_pow2(da), next_pow2(db)
    d1 = rng.choice([-1.0, 1.0], size=pa).astype(np.float32)
    d2 = rng.choice([-1.0, 1.0], size=pb).astype(np.float32)
    i1 = rng.randint(0, pa, size=m)
    i2 = rng.randint(0, pb, size=m)
    sel1t = np.zeros((pa, m), dtype=np.float32)
    sel1t[i1, np.arange(m)] = 1.0
    sel2t = np.zeros((pb, m), dtype=np.float32)
    sel2t[i2, np.arange(m)] = 1.0
    return d1, d2, sel1t, sel2t
