"""L1: blocked Walsh–Hadamard transform as MXU matmuls.

GPU implementations butterfly FWHT through shared memory; the TPU-shaped
formulation uses H_n = (H_a ⊗ I_c)(I_a ⊗ H_c): each stage contracts a
≤128-wide axis against a dense Hadamard block H_b held in VMEM — i.e. a
batched matmul on the systolic array (the `matmul.py` kernel). For
n ≤ 128 one stage suffices; n ≤ 16384 needs two.
"""

import numpy as np
import jax.numpy as jnp

from . import matmul


def hadamard_matrix(n: int) -> np.ndarray:
    """Dense H_n (entries ±1), unnormalized. n must be a power of two."""
    assert n >= 1 and (n & (n - 1)) == 0, f"n={n} not a power of two"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def _factor(n: int, max_block: int = 128):
    """Split n into power-of-two stage sizes each ≤ max_block."""
    fs = []
    rem = n
    while rem > max_block:
        fs.append(max_block)
        assert rem % max_block == 0
        rem //= max_block
    fs.append(rem)
    return fs


def needed_block_sizes(n: int) -> set:
    """Hadamard block sizes fwht_norm will contract against for length n."""
    return set(_factor(n))


def fwht_norm(x, hblocks=None, *, interpret: bool = True):
    """Orthonormal FWHT over the last axis of x: [B, n] -> [B, n].

    n must be a power of two. Decomposes into stages of Hadamard-block
    matmuls executed by the Pallas matmul kernel.

    `hblocks` maps block size -> H_f array. Pass the blocks as *traced
    parameters* when the function will be AOT-lowered: `as_hlo_text()`
    elides constants larger than a few elements (`constant({...})`) and
    the xla_extension-0.5.1 text parser silently reads the elision as
    zeros — baked-in Hadamard constants therefore vanish on the Rust
    side. (aot.py asserts the lowered text has no elided constants.)
    """
    b, n = x.shape
    assert (n & (n - 1)) == 0, f"fwht: n={n} not a power of two"
    out = x
    # H_n = prod over stages: contract each factor axis with H_f.
    # view x as [B, f1, f2, ..., fk]; stage i contracts axis i+1.
    factors = _factor(n)
    k = len(factors)
    out = out.reshape((b,) + tuple(factors))
    for i, f in enumerate(factors):
        if hblocks is not None and f in hblocks:
            h = hblocks[f]
        else:
            h = jnp.asarray(hadamard_matrix(f))
        # move axis i+1 last, flatten, matmul, restore
        perm = list(range(out.ndim))
        perm.append(perm.pop(i + 1))
        moved = out.transpose(perm)
        lead = moved.shape[:-1]
        flat = moved.reshape((-1, f))
        flat = matmul.matmul_act(flat, h, interpret=interpret)
        moved = flat.reshape(lead + (f,))
        inv = list(range(out.ndim))
        inv.insert(i + 1, inv.pop(-1))
        out = moved.transpose(inv)
    out = out.reshape(b, n)
    return out / jnp.sqrt(jnp.asarray(float(n), dtype=x.dtype))
